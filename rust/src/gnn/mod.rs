//! GNN policy handling on the Rust side.
//!
//! A GNN policy *genome* is the flat f32 parameter vector defined by the
//! L2 model (`python/compile/model.py`); evolution mutates and crosses it
//! as a raw gene string, and [`PolicyRunner`] evaluates it by executing
//! the AOT `policy_fwd_<N>` artifact through PJRT. The environment's
//! feature matrix / adjacency / mask are constants per workload, so their
//! literals are built once at runner construction and reused every call —
//! the per-rollout cost is one parameter upload + one execute.

use std::sync::Arc;

use crate::env::MappingEnv;
use crate::graph::features;
use crate::mapping::MemoryMap;
use crate::runtime::{literal_f32, literal_to_f32, Executable, Runtime};
use crate::utils::math::clamp;
use crate::utils::Rng;
use crate::xla;

/// Evaluates GNN parameter vectors against one workload environment.
pub struct PolicyRunner {
    exe: Arc<Executable>,
    /// Artifact (padded) node count.
    pub n_artifact: usize,
    /// Real node count of the workload.
    pub n_real: usize,
    /// Expected parameter vector length.
    pub param_len: usize,
    feats: xla::Literal,
    adj: xla::Literal,
    mask: xla::Literal,
}

impl PolicyRunner {
    /// Build a runner for `env`, selecting the smallest artifact variant
    /// that fits the workload.
    pub fn for_env(rt: &Runtime, env: &MappingEnv) -> anyhow::Result<PolicyRunner> {
        let n_real = env.num_nodes();
        let n_artifact = rt.manifest.size_for(n_real)?;
        let exe = rt.policy_fwd(n_real)?;
        let f = rt.manifest.feature_dim;
        let feats_v = features::padded_feature_matrix(&env.graph, n_artifact);
        let adj_v = env.graph.normalized_adjacency(n_artifact);
        let mask_v = env.graph.node_mask(n_artifact);
        Ok(PolicyRunner {
            exe,
            n_artifact,
            n_real,
            param_len: rt.manifest.actor_size,
            feats: literal_f32(&feats_v, &[n_artifact, f]),
            adj: literal_f32(&adj_v, &[n_artifact, n_artifact]),
            mask: literal_f32(&mask_v, &[n_artifact]),
        })
    }

    /// Action probabilities `[n_artifact * 2 * 3]` for a parameter vector.
    /// Only the first `n_real` node rows are meaningful. The workload
    /// constants (features/adjacency/mask) are cached literals passed by
    /// reference — the per-call upload is just the parameter vector.
    pub fn probs(&self, params: &[f32]) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(params.len() == self.param_len, "param length mismatch");
        let params_lit = literal_f32(params, &[params.len()]);
        let out = self
            .exe
            .run_refs(&[&params_lit, &self.feats, &self.adj, &self.mask])?;
        literal_to_f32(&out[0])
    }

    /// Greedy (argmax) memory map from policy probabilities.
    pub fn greedy_map(&self, probs: &[f32]) -> MemoryMap {
        self.map_from_probs(probs, None)
    }

    /// Stochastic map sampled from the policy distribution.
    pub fn sample_map(&self, probs: &[f32], rng: &mut Rng) -> MemoryMap {
        self.map_from_probs(probs, Some(rng))
    }

    /// Action-space-noise exploration for the PG actor (paper Appendix C
    /// "Mixed Exploration"): perturb the probabilities with clipped
    /// Gaussian noise, renormalize, then sample.
    pub fn noisy_sample_map(&self, probs: &[f32], noise_std: f32, rng: &mut Rng) -> MemoryMap {
        let mut actions = Vec::with_capacity(self.n_real);
        for node in 0..self.n_real {
            let mut pair = [0usize; 2];
            for (k, slot) in pair.iter_mut().enumerate() {
                let base = (node * 2 + k) * 3;
                let mut p = [0f32; 3];
                let mut z = 0f32;
                for c in 0..3 {
                    let noisy =
                        clamp(probs[base + c] + (rng.normal() as f32) * noise_std, 0.0, 10.0);
                    p[c] = noisy.max(1e-6);
                    z += p[c];
                }
                for x in p.iter_mut() {
                    *x /= z;
                }
                *slot = rng.categorical(&p);
            }
            actions.push(pair);
        }
        MemoryMap::from_actions(&actions)
    }

    fn map_from_probs(&self, probs: &[f32], mut rng: Option<&mut Rng>) -> MemoryMap {
        assert!(probs.len() >= self.n_real * 6);
        let mut actions = Vec::with_capacity(self.n_real);
        for node in 0..self.n_real {
            let mut pair = [0usize; 2];
            for (k, slot) in pair.iter_mut().enumerate() {
                let base = (node * 2 + k) * 3;
                let p = &probs[base..base + 3];
                *slot = match rng.as_deref_mut() {
                    Some(r) => r.categorical(p),
                    None => crate::utils::math::argmax(p),
                };
            }
            actions.push(pair);
        }
        MemoryMap::from_actions(&actions)
    }
}

/// Gaussian perturbation of a parameter vector — used both to diversify
/// the initial EA population from the AOT init and as the GNN mutation
/// operator (weight-space exploration).
pub fn perturb_params(params: &[f32], std: f32, frac: f64, rng: &mut Rng) -> Vec<f32> {
    params
        .iter()
        .map(|&w| {
            if rng.chance(frac) {
                w + (rng.normal() as f32) * std
            } else {
                w
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perturb_changes_roughly_frac_genes() {
        let params = vec![0f32; 10_000];
        let mut rng = Rng::new(5);
        let out = perturb_params(&params, 0.1, 0.3, &mut rng);
        let changed = out.iter().filter(|&&x| x != 0.0).count();
        assert!((2500..3500).contains(&changed), "changed={changed}");
    }

    #[test]
    fn perturb_zero_frac_is_identity() {
        let params = vec![1.5f32; 100];
        let mut rng = Rng::new(6);
        assert_eq!(perturb_params(&params, 0.1, 0.0, &mut rng), params);
    }
}
