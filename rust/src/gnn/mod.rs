//! GNN policy handling on the Rust side.
//!
//! A GNN policy *genome* is the flat f32 parameter vector defined by the
//! L2 model (`python/compile/model.py`); evolution mutates and crosses it
//! as a raw gene string, and [`PolicyRunner`] evaluates it against one
//! workload through one of two backends (DESIGN.md §15):
//!
//! * **Aot** — the original PJRT path: executes the `policy_fwd_<N>` AOT
//!   artifact on a dense padded adjacency. Fixed-shape, O(n²), requires
//!   built artifacts; kept as the numerical oracle.
//! * **Native** — [`native::NativeEngine`]: the pure-Rust sparse engine,
//!   O(E) per layer, no padding, no artifact ceiling. `Send + Sync`, so
//!   rollout workers decode genomes in parallel.
//!
//! The two backends agree within 1e-4 on action probabilities (property
//! test below, gated on artifacts being built). Backend choice is the
//! `gnn_backend` config key, resolved in `coordinator::Trainer::new`.

use std::sync::Arc;

use crate::env::MappingEnv;
use crate::graph::features;
use crate::mapping::MemoryMap;
use crate::runtime::{literal_f32, literal_to_f32, Executable, Runtime};
use crate::utils::math::clamp;
use crate::utils::Rng;
use crate::xla;

pub mod native;

pub use native::{NativeEngine, NativeWorkspace};

/// Untiled dense workload constants an [`AotRunner`] was built from, kept
/// behind an `Arc` so `SacLearner` can tile them for the update artifact
/// without recomputing the O(n²) adjacency (ISSUE 8 satellite).
pub struct AotConstants {
    pub n_artifact: usize,
    pub feats: Vec<f32>,
    pub adj: Vec<f32>,
    pub mask: Vec<f32>,
}

/// The PJRT artifact backend: uploads the genome, executes the padded
/// dense forward. Workload constants are cached literals built once.
pub struct AotRunner {
    exe: Arc<Executable>,
    /// Artifact (padded) node count.
    pub n_artifact: usize,
    /// Real node count of the workload.
    pub n_real: usize,
    /// Expected parameter vector length.
    pub param_len: usize,
    feats: xla::Literal,
    adj: xla::Literal,
    mask: xla::Literal,
    /// The host-side vectors the literals were built from.
    pub constants: Arc<AotConstants>,
}

impl AotRunner {
    /// Build a runner for `env`, selecting the smallest artifact variant
    /// that fits the workload.
    pub fn for_env(rt: &Runtime, env: &MappingEnv) -> anyhow::Result<AotRunner> {
        let n_real = env.num_nodes();
        let n_artifact = rt.manifest.size_for(n_real)?;
        let exe = rt.policy_fwd(n_real)?;
        let f = rt.manifest.feature_dim;
        let constants = Arc::new(AotConstants {
            n_artifact,
            feats: features::padded_feature_matrix(&env.graph, n_artifact),
            adj: env.graph.normalized_adjacency(n_artifact),
            mask: env.graph.node_mask(n_artifact),
        });
        Ok(AotRunner {
            exe,
            n_artifact,
            n_real,
            param_len: rt.manifest.actor_size,
            feats: literal_f32(&constants.feats, &[n_artifact, f]),
            adj: literal_f32(&constants.adj, &[n_artifact, n_artifact]),
            mask: literal_f32(&constants.mask, &[n_artifact]),
            constants,
        })
    }

    /// Action probabilities `[n_artifact * 2 * 3]` for a parameter vector.
    /// Only the first `n_real` node rows are meaningful.
    pub fn probs(&self, params: &[f32]) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(params.len() == self.param_len, "param length mismatch");
        let params_lit = literal_f32(params, &[params.len()]);
        let out = self
            .exe
            .run_refs(&[&params_lit, &self.feats, &self.adj, &self.mask])?;
        literal_to_f32(&out[0])
    }
}

/// Evaluates GNN parameter vectors against one workload environment,
/// through whichever backend the trainer resolved.
pub enum PolicyRunner {
    Aot(AotRunner),
    Native(NativeEngine),
}

impl PolicyRunner {
    /// AOT-backed runner (requires a PJRT runtime + built artifacts).
    pub fn aot_for_env(rt: &Runtime, env: &MappingEnv) -> anyhow::Result<PolicyRunner> {
        Ok(PolicyRunner::Aot(AotRunner::for_env(rt, env)?))
    }

    /// Native sparse runner — no runtime, no artifacts, no size ceiling.
    pub fn native_for_env(env: &MappingEnv) -> PolicyRunner {
        PolicyRunner::Native(NativeEngine::for_graph(&env.graph))
    }

    /// Real node count of the workload.
    pub fn n_real(&self) -> usize {
        match self {
            PolicyRunner::Aot(r) => r.n_real,
            PolicyRunner::Native(e) => e.n(),
        }
    }

    /// Expected parameter vector length.
    pub fn param_len(&self) -> usize {
        match self {
            PolicyRunner::Aot(r) => r.param_len,
            PolicyRunner::Native(e) => e.param_len(),
        }
    }

    /// Artifact (padded) size — `None` on the native backend.
    pub fn n_artifact(&self) -> Option<usize> {
        match self {
            PolicyRunner::Aot(r) => Some(r.n_artifact),
            PolicyRunner::Native(_) => None,
        }
    }

    /// True when decode is a pure in-process function — the precondition
    /// for folding decode into the parallel rollout workers (§15).
    pub fn is_native(&self) -> bool {
        matches!(self, PolicyRunner::Native(_))
    }

    /// The native engine, when that backend is active.
    pub fn native_engine(&self) -> Option<&NativeEngine> {
        match self {
            PolicyRunner::Native(e) => Some(e),
            PolicyRunner::Aot(_) => None,
        }
    }

    /// The AOT runner's shared dense constants, when that backend is active.
    pub fn aot_constants(&self) -> Option<&Arc<AotConstants>> {
        match self {
            PolicyRunner::Aot(r) => Some(&r.constants),
            PolicyRunner::Native(_) => None,
        }
    }

    /// Action probabilities for a parameter vector. Rows beyond `n_real`
    /// (AOT padding) are meaningless; consumers index by real node.
    pub fn probs(&self, params: &[f32]) -> anyhow::Result<Vec<f32>> {
        match self {
            PolicyRunner::Aot(r) => r.probs(params),
            PolicyRunner::Native(e) => e.probs(params),
        }
    }

    /// Workspace-reusing variant for hot loops: the native backend runs
    /// allocation-free into `ws`; the AOT backend ignores it (PJRT owns
    /// its buffers).
    pub fn probs_with(&self, params: &[f32], ws: &mut NativeWorkspace) -> anyhow::Result<Vec<f32>> {
        match self {
            PolicyRunner::Aot(r) => r.probs(params),
            PolicyRunner::Native(e) => {
                anyhow::ensure!(params.len() == e.param_len(), "param length mismatch");
                Ok(e.probs_into(params, ws).to_vec())
            }
        }
    }

    /// Greedy (argmax) memory map from policy probabilities.
    pub fn greedy_map(&self, probs: &[f32]) -> MemoryMap {
        self.map_from_probs(probs, None)
    }

    /// Stochastic map sampled from the policy distribution.
    pub fn sample_map(&self, probs: &[f32], rng: &mut Rng) -> MemoryMap {
        self.map_from_probs(probs, Some(rng))
    }

    /// Action-space-noise exploration for the PG actor (paper Appendix C
    /// "Mixed Exploration"): perturb the probabilities with clipped
    /// Gaussian noise, renormalize, then sample.
    pub fn noisy_sample_map(&self, probs: &[f32], noise_std: f32, rng: &mut Rng) -> MemoryMap {
        let n_real = self.n_real();
        let mut actions = Vec::with_capacity(n_real);
        for node in 0..n_real {
            let mut pair = [0usize; 2];
            for (k, slot) in pair.iter_mut().enumerate() {
                let base = (node * 2 + k) * 3;
                let mut p = [0f32; 3];
                let mut z = 0f32;
                for c in 0..3 {
                    let noisy =
                        clamp(probs[base + c] + (rng.normal() as f32) * noise_std, 0.0, 10.0);
                    p[c] = noisy.max(1e-6);
                    z += p[c];
                }
                for x in p.iter_mut() {
                    *x /= z;
                }
                *slot = rng.categorical(&p);
            }
            actions.push(pair);
        }
        MemoryMap::from_actions(&actions)
    }

    fn map_from_probs(&self, probs: &[f32], mut rng: Option<&mut Rng>) -> MemoryMap {
        let n_real = self.n_real();
        assert!(probs.len() >= n_real * 6);
        let mut actions = Vec::with_capacity(n_real);
        for node in 0..n_real {
            let mut pair = [0usize; 2];
            for (k, slot) in pair.iter_mut().enumerate() {
                let base = (node * 2 + k) * 3;
                let p = &probs[base..base + 3];
                *slot = match rng.as_deref_mut() {
                    Some(r) => r.categorical(p),
                    None => crate::utils::math::argmax(p),
                };
            }
            actions.push(pair);
        }
        MemoryMap::from_actions(&actions)
    }
}

/// In-place Gaussian perturbation of a parameter vector — the GNN mutation
/// operator (weight-space exploration). Per-gene draw order (`chance`,
/// then `normal` on a hit) is identical to the historical allocating
/// version, so existing seeds reproduce bit-identically.
pub fn perturb_params_into(params: &mut [f32], std: f32, frac: f64, rng: &mut Rng) {
    for w in params.iter_mut() {
        if rng.chance(frac) {
            *w += (rng.normal() as f32) * std;
        }
    }
}

/// Allocating wrapper over [`perturb_params_into`] — used to diversify the
/// initial EA population from a seed genome.
pub fn perturb_params(params: &[f32], std: f32, frac: f64, rng: &mut Rng) -> Vec<f32> {
    let mut out = params.to_vec();
    perturb_params_into(&mut out, std, frac, rng);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::synthetic::{synthetic, SyntheticConfig};

    #[test]
    fn perturb_changes_roughly_frac_genes() {
        let params = vec![0f32; 10_000];
        let mut rng = Rng::new(5);
        let out = perturb_params(&params, 0.1, 0.3, &mut rng);
        let changed = out.iter().filter(|&&x| x != 0.0).count();
        assert!((2500..3500).contains(&changed), "changed={changed}");
    }

    #[test]
    fn perturb_zero_frac_is_identity() {
        let params = vec![1.5f32; 100];
        let mut rng = Rng::new(6);
        assert_eq!(perturb_params(&params, 0.1, 0.0, &mut rng), params);
    }

    #[test]
    fn perturb_into_matches_allocating_version() {
        let mut rng = Rng::new(17);
        let params: Vec<f32> = (0..500).map(|_| rng.normal() as f32).collect();
        let mut a_rng = Rng::new(99);
        let mut b_rng = Rng::new(99);
        let out = perturb_params(&params, 0.07, 0.4, &mut a_rng);
        let mut inplace = params.clone();
        perturb_params_into(&mut inplace, 0.07, 0.4, &mut b_rng);
        assert_eq!(out, inplace);
        // The RNG streams advanced identically too.
        assert_eq!(a_rng.next_u64(), b_rng.next_u64());
    }

    #[test]
    fn native_runner_decodes_maps() {
        let cfg = SyntheticConfig { nodes: 20, ..Default::default() };
        let g = synthetic(&cfg, &mut Rng::new(3));
        let env = MappingEnv::nnpi(g, 3);
        let runner = PolicyRunner::native_for_env(&env);
        assert!(runner.is_native());
        assert_eq!(runner.n_real(), 20);
        assert_eq!(runner.n_artifact(), None);
        assert_eq!(runner.param_len(), native::ACTOR_SIZE);
        let params = native::init_actor_params(&mut Rng::new(3));
        let probs = runner.probs(&params).unwrap();
        let map = runner.greedy_map(&probs);
        assert_eq!(map.to_actions().len(), 20);
        let mut rng = Rng::new(4);
        let _ = runner.sample_map(&probs, &mut rng);
        let _ = runner.noisy_sample_map(&probs, 0.1, &mut rng);
    }

    #[test]
    fn native_matches_aot_artifact_within_tolerance() {
        // The backend-parity contract (§15): on a workload that fits the
        // smallest artifact, native probabilities equal the AOT output
        // within 1e-4 on all real rows, with the pool size pinned to the
        // artifact's padded semantics. Gated: needs built artifacts.
        if !Runtime::default_dir().join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        // Artifacts present but no PJRT device backend in this build
        // (the crate::xla stand-in): the contract cannot be executed,
        // only skipped — main.rs degrades the same way at startup.
        let Ok(rt) = Runtime::open(Runtime::default_dir()) else {
            eprintln!("skipping: artifacts present but no device backend in this build");
            return;
        };
        let cfg = SyntheticConfig { nodes: 48, ..Default::default() };
        let g = synthetic(&cfg, &mut Rng::new(11));
        let env = MappingEnv::nnpi(g, 11);
        let aot = PolicyRunner::aot_for_env(&rt, &env).unwrap();
        assert_eq!(
            aot.param_len(),
            native::ACTOR_SIZE,
            "manifest actor_size disagrees with the native layout"
        );
        let n_real = env.num_nodes();
        let n_art = aot.n_artifact().unwrap();
        let k_eff = native::pool_k(n_art).min(n_real);
        let engine = NativeEngine::for_graph(&env.graph).with_pool_k(k_eff);
        let actor = rt.actor_init().unwrap();
        let got = engine.probs(&actor).unwrap();
        let want = aot.probs(&actor).unwrap();
        for (i, (&a, &b)) in got.iter().zip(&want[..n_real * 6]).enumerate() {
            assert!(
                (a - b).abs() < 1e-4,
                "native/AOT diverge at {i}: native={a} aot={b}"
            );
        }
    }
}
