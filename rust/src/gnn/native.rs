//! Pure-Rust sparse inference + training engine for the Graph U-Net policy.
//!
//! Executes the exact architecture of `python/compile/model.py` — feature
//! scaling → input projection → GAT conv ×4 (4 heads) → top-k gated pooling
//! at N/4 → unpool + skip → per-node 2×3 action head — over the CSR
//! adjacency from [`crate::graph::CsrAdjacency`] instead of the dense padded
//! operator the AOT artifacts consume. Cost is O(E·H·D) per layer with no
//! padding and no artifact-size ceiling, which is what lets the full EGRL
//! agent run at 100k nodes (DESIGN.md §15).
//!
//! The flat parameter vector is the same genome the EA mutates and the AOT
//! artifacts `unflatten`: layout constants below mirror `ACTOR_SPEC`
//! (`trunk_spec` in model.py) exactly, asserted against the manifest sizes
//! in tests. [`dense_reference_probs`] is a literal dense transcription of
//! model.py (including padding and pool-k semantics) kept as the oracle for
//! the sparse path and for AOT parity.
//!
//! One semantic caveat, load-bearing for parity tests: model.py computes
//! `k = pool_k(feats.shape[0])`, i.e. the pool size depends on the *padded*
//! artifact size, not the real node count. The native engine therefore takes
//! `k` as a parameter ([`NativeEngine::with_pool_k`]); pure-native runs use
//! `pool_k(n_real)`, while AOT-parity comparisons must pass
//! `min(pool_k(n_artifact), n_real)` (padding rows score −1e9, so at most
//! `n_real` padding-free slots ever carry signal).
//!
//! [`NativeSacLearner`] is the matching pure-Rust port of
//! `python/compile/sac.py`: same masked means, twin-Q min, noisy one-hot
//! draw order, Adam constants and update order (critic step, then actor
//! against the *updated* critic). Because the batch state tensors are
//! workload constants, per-choice Q and π are batch-independent and the
//! batched gradient collapses to a weighted single-graph backward — one
//! update costs ~5 trunk forwards + 3 backwards regardless of batch size.

use std::sync::Arc;

use crate::graph::{features, CsrAdjacency, Graph};
use crate::rl::replay::Transition;
use crate::rl::sac::SacMetrics;
use crate::utils::math::clamp;
use crate::utils::Rng;

// ---- dimensions (mirror python/compile/model.py; manifest-checked) ---------

pub const FEATURE_DIM: usize = features::DIM;
pub const HIDDEN: usize = 64;
pub const HEADS: usize = 4;
pub const HEAD_DIM: usize = HIDDEN / HEADS;
pub const NUM_LAYERS: usize = 4;
pub const SUBACTIONS: usize = 2;
pub const CHOICES: usize = 3;
pub const OUT_DIM: usize = SUBACTIONS * CHOICES;
pub const POOL_RATIO: usize = 4;
const LEAKY_SLOPE: f32 = 0.2;

/// Per-feature normalization divisors, Table-1 order (model.py
/// `FEATURE_SCALE` verbatim).
pub const FEATURE_SCALE: [f32; FEATURE_DIM] = [
    12.0, 25.0, 400.0, 256.0, 13.0, 400.0, 256.0, 13.0, 25.0, 25.0, 400.0, 28.0, 32.0, 8.0, 8.0,
    4.0, 4.0, 2.0, 1.0,
];

/// Pooled node count for an `n`-row forward (model.py `pool_k`).
pub fn pool_k(n: usize) -> usize {
    (n / POOL_RATIO).max(1)
}

// ---- flat-parameter layout (ACTOR_SPEC order: w_in, b_in, per layer × head
//      (w, a_src, a_dst), pool_p, w_out, b_out) --------------------------------

const W_IN_OFF: usize = 0;
const W_IN_LEN: usize = FEATURE_DIM * HIDDEN;
const B_IN_OFF: usize = W_IN_OFF + W_IN_LEN;
const LAYERS_OFF: usize = B_IN_OFF + HIDDEN;
const HEAD_W_LEN: usize = HIDDEN * HEAD_DIM;
const PER_HEAD: usize = HEAD_W_LEN + 2 * HEAD_DIM;
const PER_LAYER: usize = HEADS * PER_HEAD;
const POOL_P_OFF: usize = LAYERS_OFF + NUM_LAYERS * PER_LAYER;
const W_OUT_OFF: usize = POOL_P_OFF + HIDDEN;
const W_OUT_LEN: usize = HIDDEN * OUT_DIM;
const B_OUT_OFF: usize = W_OUT_OFF + W_OUT_LEN;

/// Flat actor-parameter count — must equal the manifest's `actor_size`.
pub const ACTOR_SIZE: usize = B_OUT_OFF + OUT_DIM;
/// Twin critic: two independent trunks.
pub const CRITIC_SIZE: usize = 2 * ACTOR_SIZE;

fn head_off(layer: usize, head: usize) -> usize {
    LAYERS_OFF + layer * PER_LAYER + head * PER_HEAD
}

struct HeadView<'a> {
    w: &'a [f32],     // [HIDDEN, HEAD_DIM] row-major
    a_src: &'a [f32], // [HEAD_DIM]
    a_dst: &'a [f32], // [HEAD_DIM]
}

fn head_view(p: &[f32], layer: usize, head: usize) -> HeadView<'_> {
    let o = head_off(layer, head);
    HeadView {
        w: &p[o..o + HEAD_W_LEN],
        a_src: &p[o + HEAD_W_LEN..o + HEAD_W_LEN + HEAD_DIM],
        a_dst: &p[o + HEAD_W_LEN + HEAD_DIM..o + PER_HEAD],
    }
}

// ---- native parameter init (model.py init_trunk semantics) ------------------

fn init_trunk_into(out: &mut Vec<f32>, rng: &mut Rng) {
    let glorot = |rng: &mut Rng, out: &mut Vec<f32>, fan_in: usize, fan_out: usize, scale: f32| {
        let lim = (6.0 / (fan_in + fan_out) as f32).sqrt();
        for _ in 0..fan_in * fan_out {
            out.push(rng.range_f64(-lim as f64, lim as f64) as f32 * scale);
        }
    };
    glorot(rng, out, FEATURE_DIM, HIDDEN, 1.0); // w_in
    let blen = out.len() + HIDDEN;
    out.resize(blen, 0.0); // b_in
    for _layer in 0..NUM_LAYERS {
        for _head in 0..HEADS {
            glorot(rng, out, HIDDEN, HEAD_DIM, 1.0); // w
            for _ in 0..2 * HEAD_DIM {
                out.push(0.1 * rng.normal() as f32); // a_src, a_dst
            }
        }
    }
    for _ in 0..HIDDEN {
        out.push(0.1 * rng.normal() as f32); // pool_p
    }
    glorot(rng, out, HIDDEN, OUT_DIM, 0.1); // w_out (small head scale)
    for c in 0..OUT_DIM {
        // Logit bias toward choice 0 (DRAM) for every sub-action.
        out.push(if c % CHOICES == 0 { 2.5 } else { 0.0 });
    }
}

/// Fresh flat actor parameters (Glorot matrices, DRAM-biased output head).
/// Distributionally equivalent to model.py `init_actor`, drawn from the
/// Rust RNG — bit-equality with the JAX init is neither needed nor claimed.
pub fn init_actor_params(rng: &mut Rng) -> Vec<f32> {
    let mut out = Vec::with_capacity(ACTOR_SIZE);
    init_trunk_into(&mut out, rng);
    debug_assert_eq!(out.len(), ACTOR_SIZE);
    out
}

/// Fresh flat twin-critic parameters (two independent trunks).
pub fn init_critic_params(rng: &mut Rng) -> Vec<f32> {
    let mut out = Vec::with_capacity(CRITIC_SIZE);
    init_trunk_into(&mut out, rng);
    init_trunk_into(&mut out, rng);
    debug_assert_eq!(out.len(), CRITIC_SIZE);
    out
}

// ---- per-workload constants --------------------------------------------------

/// Graph constants the GNN consumes, built once per workload and shared
/// (via `Arc`) between the policy runner and the SAC learner — the fix for
/// the former per-learner dense O(n²) rebuild.
pub struct GraphCache {
    /// Real node count.
    pub n: usize,
    /// Row-major `[n, FEATURE_DIM]`, already divided by [`FEATURE_SCALE`].
    pub feats_scaled: Vec<f32>,
    /// Degree-normalized sparse adjacency (self-loops included).
    pub csr: CsrAdjacency,
}

impl GraphCache {
    pub fn build(g: &Graph) -> GraphCache {
        let mut feats_scaled = g.feature_matrix();
        for row in feats_scaled.chunks_exact_mut(FEATURE_DIM) {
            for (x, &s) in row.iter_mut().zip(&FEATURE_SCALE) {
                *x /= s;
            }
        }
        GraphCache { n: g.len(), feats_scaled, csr: g.csr_adjacency() }
    }
}

// ---- forward tape ------------------------------------------------------------

fn fit(v: &mut Vec<f32>, len: usize) {
    v.clear();
    v.resize(len, 0.0);
}

#[derive(Default)]
struct GatTape {
    /// Head-major `[HEADS][m, HEAD_DIM]` projections.
    proj: Vec<f32>,
    /// Head-major `[HEADS][m]` source / destination attention scores.
    s_src: Vec<f32>,
    s_dst: Vec<f32>,
    /// `[m, HIDDEN]` post-relu layer output.
    out: Vec<f32>,
}

/// Everything one trunk forward saves — enough for the manual backward to
/// recompute attention rows without storing O(E) weights per head.
#[derive(Default)]
struct TrunkTape {
    h0: Vec<f32>, // [n, HIDDEN] input embedding (post-tanh)
    l0: GatTape,  // encoder; h1 = l0.out
    uvec: Vec<f32>,   // normalized pool_p [HIDDEN]
    scores: Vec<f32>, // [n]
    order: Vec<u32>,  // nodes sorted by (score desc, idx asc); first k selected
    gate: Vec<f32>,   // [n] sigmoid(scores)
    hp: Vec<f32>,     // [k, HIDDEN] pooled gated features
    adj_p: CsrAdjacency, // induced pooled adjacency (rank order)
    l1: GatTape,      // bottleneck; h2 = l1.out
    h_up: Vec<f32>,   // [n, HIDDEN] unpooled + skip
    l2: GatTape,
    l3: GatTape, // h4 = l3.out
    logits: Vec<f32>, // [n, OUT_DIM]
    probs: Vec<f32>,  // [n, OUT_DIM] (policy forward only)
    k: usize,
    row_w: Vec<f32>,              // attention-weight scratch, one row
    pairs: Vec<(u32, f32)>,       // pooled-row column sort scratch
    pos_of: Vec<i32>,             // node -> pooled rank, or -1
}

/// Reusable forward scratch for one decode stream — `Default` + `Send`, so
/// `map_parallel` workers each own one and decode genomes with zero
/// steady-state allocation.
#[derive(Default)]
pub struct NativeWorkspace {
    tape: TrunkTape,
}

// ---- small dense kernels -----------------------------------------------------

/// `out[m,p] = a[m,q] @ b[q,p]` (row-major, ikj order).
fn matmul(a: &[f32], q: usize, b: &[f32], p: usize, out: &mut [f32]) {
    for (arow, orow) in a.chunks_exact(q).zip(out.chunks_exact_mut(p)) {
        orow.fill(0.0);
        for (&av, brow) in arow.iter().zip(b.chunks_exact(p)) {
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// `out[q,p] += a[m,q]ᵀ @ b[m,p]`.
fn matmul_t_acc(a: &[f32], q: usize, b: &[f32], p: usize, out: &mut [f32]) {
    for (arow, brow) in a.chunks_exact(q).zip(b.chunks_exact(p)) {
        for (&av, orow) in arow.iter().zip(out.chunks_exact_mut(p)) {
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// `out[m,q] += a[m,p] @ b[q,p]ᵀ`.
fn matmul_bt_acc(a: &[f32], p: usize, b: &[f32], q: usize, out: &mut [f32]) {
    for (arow, orow) in a.chunks_exact(p).zip(out.chunks_exact_mut(q)) {
        for (o, brow) in orow.iter_mut().zip(b.chunks_exact(p)) {
            *o += arow.iter().zip(brow).map(|(&x, &y)| x * y).sum::<f32>();
        }
    }
}

fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(&x, &y)| x * y).sum()
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

fn leaky(x: f32) -> f32 {
    if x >= 0.0 {
        x
    } else {
        LEAKY_SLOPE * x
    }
}

// ---- the engine --------------------------------------------------------------

/// Sparse Graph U-Net executor for one workload. Cheap to clone conceptually
/// (the graph constants live behind an `Arc`); `Send + Sync`, so rollout
/// workers evaluate genomes concurrently.
pub struct NativeEngine {
    cache: Arc<GraphCache>,
    k: usize,
}

impl NativeEngine {
    /// Build the engine (and its graph cache) for a workload graph, with
    /// the pure-native pool size `pool_k(n_real)`.
    pub fn for_graph(g: &Graph) -> NativeEngine {
        Self::from_cache(Arc::new(GraphCache::build(g)))
    }

    /// Build from an existing shared cache (no recomputation).
    pub fn from_cache(cache: Arc<GraphCache>) -> NativeEngine {
        let k = pool_k(cache.n).min(cache.n);
        NativeEngine { cache, k }
    }

    /// Override the pool size — required to reproduce an AOT artifact's
    /// output, whose `k` derives from the *padded* size (module docs).
    pub fn with_pool_k(mut self, k: usize) -> NativeEngine {
        self.k = k.clamp(1, self.cache.n);
        self
    }

    /// Real node count.
    pub fn n(&self) -> usize {
        self.cache.n
    }

    /// Effective pooled node count.
    pub fn pool_size(&self) -> usize {
        self.k
    }

    /// The shared per-workload constants.
    pub fn cache(&self) -> &Arc<GraphCache> {
        &self.cache
    }

    /// Expected flat-parameter length.
    pub fn param_len(&self) -> usize {
        ACTOR_SIZE
    }

    /// Action probabilities `[n * 2 * 3]`, allocation-free given a reused
    /// workspace. Panics on a wrong-length parameter vector (genomes are
    /// length-checked at construction).
    pub fn probs_into<'a>(&self, params: &[f32], ws: &'a mut NativeWorkspace) -> &'a [f32] {
        assert_eq!(params.len(), ACTOR_SIZE, "actor param length mismatch");
        self.trunk_logits(params, &mut ws.tape);
        let tape = &mut ws.tape;
        fit(&mut tape.probs, tape.logits.len());
        for (trip, ptrip) in tape
            .logits
            .chunks_exact(CHOICES)
            .zip(tape.probs.chunks_exact_mut(CHOICES))
        {
            let m = trip.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0.0;
            for (p, &l) in ptrip.iter_mut().zip(trip) {
                *p = (l - m).exp();
                z += *p;
            }
            for p in ptrip.iter_mut() {
                *p /= z;
            }
        }
        &tape.probs
    }

    /// Allocating convenience wrapper over [`NativeEngine::probs_into`],
    /// API-compatible with the AOT runner's `probs`.
    pub fn probs(&self, params: &[f32]) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(params.len() == ACTOR_SIZE, "param length mismatch");
        let mut ws = NativeWorkspace::default();
        Ok(self.probs_into(params, &mut ws).to_vec())
    }

    /// One trunk forward up to the `[n, 6]` head logits, recording the tape.
    fn trunk_logits(&self, p: &[f32], tape: &mut TrunkTape) {
        let n = self.cache.n;
        let k = self.k.min(n);
        tape.k = k;
        let adj = &self.cache.csr;

        // Input projection: h0 = tanh(xn @ w_in + b_in).
        fit(&mut tape.h0, n * HIDDEN);
        matmul(&self.cache.feats_scaled, FEATURE_DIM, &p[W_IN_OFF..W_IN_OFF + W_IN_LEN], HIDDEN, &mut tape.h0);
        let b_in = &p[B_IN_OFF..B_IN_OFF + HIDDEN];
        for row in tape.h0.chunks_exact_mut(HIDDEN) {
            for (x, &b) in row.iter_mut().zip(b_in) {
                *x = (*x + b).tanh();
            }
        }

        // Encoder.
        let (h0, l0, row_w) = (&tape.h0, &mut tape.l0, &mut tape.row_w);
        gat_forward(p, 0, adj, h0, l0, row_w);

        // Top-k gated pooling (selection is 0-grad; gate carries gradient).
        let pool_p = &p[POOL_P_OFF..POOL_P_OFF + HIDDEN];
        let norm = dot(pool_p, pool_p).sqrt();
        fit(&mut tape.uvec, HIDDEN);
        for (u, &x) in tape.uvec.iter_mut().zip(pool_p) {
            *u = x / (norm + 1e-8);
        }
        fit(&mut tape.scores, n);
        for (s, row) in tape.scores.iter_mut().zip(tape.l0.out.chunks_exact(HIDDEN)) {
            *s = dot(row, &tape.uvec);
        }
        tape.order.clear();
        tape.order.extend(0..n as u32);
        let scores = &tape.scores;
        tape.order.sort_unstable_by(|&a, &b| {
            scores[b as usize]
                .partial_cmp(&scores[a as usize])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        tape.pos_of.clear();
        tape.pos_of.resize(n, -1);
        for (r, &node) in tape.order[..k].iter().enumerate() {
            tape.pos_of[node as usize] = r as i32;
        }
        fit(&mut tape.gate, n);
        for (g, &s) in tape.gate.iter_mut().zip(&tape.scores) {
            *g = sigmoid(s);
        }
        fit(&mut tape.hp, k * HIDDEN);
        for (r, hprow) in tape.hp.chunks_exact_mut(HIDDEN).enumerate() {
            let i = tape.order[r] as usize;
            let g = tape.gate[i];
            for (x, &h) in hprow.iter_mut().zip(&tape.l0.out[i * HIDDEN..(i + 1) * HIDDEN]) {
                *x = h * g;
            }
        }
        induced_csr(adj, &tape.order[..k], &tape.pos_of, &mut tape.adj_p, &mut tape.pairs);

        // Bottleneck on the pooled graph.
        let (hp, adj_p, l1, row_w) = (&tape.hp, &tape.adj_p, &mut tape.l1, &mut tape.row_w);
        gat_forward(p, 1, adj_p, hp, l1, row_w);

        // Unpool (scatter) + skip connection.
        tape.h_up.clear();
        tape.h_up.extend_from_slice(&tape.l0.out);
        for (r, h2row) in tape.l1.out.chunks_exact(HIDDEN).enumerate() {
            let i = tape.order[r] as usize;
            for (x, &h2) in tape.h_up[i * HIDDEN..(i + 1) * HIDDEN].iter_mut().zip(h2row) {
                *x += h2;
            }
        }

        // Decoder.
        let (h_up, l2, row_w) = (&tape.h_up, &mut tape.l2, &mut tape.row_w);
        gat_forward(p, 2, adj, h_up, l2, row_w);
        let (h3, l3, row_w) = (&tape.l2.out, &mut tape.l3, &mut tape.row_w);
        gat_forward(p, 3, adj, h3, l3, row_w);

        // Action head.
        fit(&mut tape.logits, n * OUT_DIM);
        matmul(&tape.l3.out, HIDDEN, &p[W_OUT_OFF..W_OUT_OFF + W_OUT_LEN], OUT_DIM, &mut tape.logits);
        let b_out = &p[B_OUT_OFF..B_OUT_OFF + OUT_DIM];
        for row in tape.logits.chunks_exact_mut(OUT_DIM) {
            for (x, &b) in row.iter_mut().zip(b_out) {
                *x += b;
            }
        }
    }

    /// Manual trunk backward: given `d_logits [n, 6]`, accumulate parameter
    /// gradients into `grad [ACTOR_SIZE]` using the saved tape (attention
    /// rows are recomputed from proj/s_src/s_dst, not stored).
    fn trunk_backward(
        &self,
        p: &[f32],
        tape: &TrunkTape,
        d_logits: &[f32],
        grad: &mut [f32],
        sc: &mut BwdScratch,
    ) {
        let n = self.cache.n;
        let k = tape.k;
        let adj = &self.cache.csr;
        let BwdScratch { d_a, d_b, d_pool, d_uvec, gat } = sc;

        // Head: d_h4 = d_logits @ w_outᵀ; d_w_out += h4ᵀ @ d_logits.
        matmul_t_acc(&tape.l3.out, HIDDEN, d_logits, OUT_DIM, &mut grad[W_OUT_OFF..W_OUT_OFF + W_OUT_LEN]);
        for drow in d_logits.chunks_exact(OUT_DIM) {
            for (g, &d) in grad[B_OUT_OFF..B_OUT_OFF + OUT_DIM].iter_mut().zip(drow) {
                *g += d;
            }
        }
        fit(d_a, n * HIDDEN);
        matmul_bt_acc(d_logits, OUT_DIM, &p[W_OUT_OFF..W_OUT_OFF + W_OUT_LEN], HIDDEN, d_a);

        // Decoder layers (full adjacency).
        gat_backward(p, 3, adj, &tape.l2.out, &tape.l3, d_a, d_b, grad, gat);
        gat_backward(p, 2, adj, &tape.h_up, &tape.l2, d_b, d_a, grad, gat);

        // Unpool backward: h_up = h1 + scatter(h2) ⇒ d_h1 = d_h_up (keep in
        // d_a) and d_h2[r] = d_h_up[order[r]].
        fit(d_pool, k * HIDDEN);
        for (r, drow) in d_pool.chunks_exact_mut(HIDDEN).enumerate() {
            let i = tape.order[r] as usize;
            drow.copy_from_slice(&d_a[i * HIDDEN..(i + 1) * HIDDEN]);
        }

        // Bottleneck backward (pooled adjacency): d_h2 -> d_hp (into d_b's
        // first k rows).
        gat_backward(p, 1, &tape.adj_p, &tape.hp, &tape.l1, d_pool, d_b, grad, gat);

        // Pool backward. hp[r] = h1[i]·gate[i], scores = h1 @ uvec,
        // gate = σ(scores); the selection itself is 0-grad.
        let h1 = &tape.l0.out;
        fit(d_uvec, HIDDEN);
        for r in 0..k {
            let i = tape.order[r] as usize;
            let d_hp = &d_b[r * HIDDEN..(r + 1) * HIDDEN];
            let g = tape.gate[i];
            let h1row = &h1[i * HIDDEN..(i + 1) * HIDDEN];
            let d_gate = dot(d_hp, h1row);
            let d_score = d_gate * g * (1.0 - g);
            let drow = &mut d_a[i * HIDDEN..(i + 1) * HIDDEN];
            for ((d, &dh), &u) in drow.iter_mut().zip(d_hp).zip(tape.uvec.iter()) {
                *d += dh * g + d_score * u;
            }
            for (du, &h) in d_uvec.iter_mut().zip(h1row) {
                *du += d_score * h;
            }
        }
        // uvec = pool_p / (‖pool_p‖ + 1e-8).
        let pool_p = &p[POOL_P_OFF..POOL_P_OFF + HIDDEN];
        let s = dot(pool_p, pool_p).sqrt();
        let denom = s + 1e-8;
        let p_dot_du = dot(pool_p, d_uvec);
        for ((g, &du), &pv) in grad[POOL_P_OFF..POOL_P_OFF + HIDDEN]
            .iter_mut()
            .zip(d_uvec.iter())
            .zip(pool_p)
        {
            *g += du / denom
                - if s > 0.0 {
                    pv * p_dot_du / (s * denom * denom)
                } else {
                    0.0
                };
        }

        // Encoder backward.
        gat_backward(p, 0, adj, &tape.h0, &tape.l0, d_a, d_b, grad, gat);

        // Input projection backward: h0 = tanh(z) ⇒ d_z = d_h0 · (1 − h0²).
        for (drow, hrow) in d_b.chunks_exact_mut(HIDDEN).zip(tape.h0.chunks_exact(HIDDEN)) {
            for (d, &h) in drow.iter_mut().zip(hrow) {
                *d *= 1.0 - h * h;
            }
        }
        matmul_t_acc(&self.cache.feats_scaled, FEATURE_DIM, d_b, HIDDEN, &mut grad[W_IN_OFF..W_IN_OFF + W_IN_LEN]);
        for drow in d_b.chunks_exact(HIDDEN) {
            for (g, &d) in grad[B_IN_OFF..B_IN_OFF + HIDDEN].iter_mut().zip(drow) {
                *g += d;
            }
        }
    }
}

/// Induced pooled adjacency `adj_p[r][c] = adj[order[r]][order[c]]` — the
/// sparse equivalent of `sel @ adj @ selᵀ`, rows in rank order, columns
/// sorted ascending.
fn induced_csr(
    adj: &CsrAdjacency,
    selected: &[u32],
    pos_of: &[i32],
    out: &mut CsrAdjacency,
    pairs: &mut Vec<(u32, f32)>,
) {
    out.n = selected.len();
    out.row_ptr.clear();
    out.row_ptr.push(0);
    out.col_idx.clear();
    out.values.clear();
    for &node in selected {
        pairs.clear();
        let (cols, vals) = adj.row(node as usize);
        for (&c, &v) in cols.iter().zip(vals) {
            let r = pos_of[c as usize];
            if r >= 0 {
                pairs.push((r as u32, v));
            }
        }
        pairs.sort_unstable_by_key(|&(c, _)| c);
        for &(c, v) in pairs.iter() {
            out.col_idx.push(c);
            out.values.push(v);
        }
        out.row_ptr.push(out.col_idx.len());
    }
}

/// One 4-head GAT convolution with residual + relu over a CSR neighborhood
/// (adjacency values act purely as an edge mask, exactly like the kernel's
/// `adj > 0` predicate).
fn gat_forward(
    p: &[f32],
    layer: usize,
    adj: &CsrAdjacency,
    u: &[f32],
    tape: &mut GatTape,
    row_w: &mut Vec<f32>,
) {
    let m = adj.n;
    fit(&mut tape.proj, HEADS * m * HEAD_DIM);
    fit(&mut tape.s_src, HEADS * m);
    fit(&mut tape.s_dst, HEADS * m);
    tape.out.clear();
    tape.out.extend_from_slice(u); // residual
    for h in 0..HEADS {
        let hv = head_view(p, layer, h);
        let proj = &mut tape.proj[h * m * HEAD_DIM..(h + 1) * m * HEAD_DIM];
        matmul(u, HIDDEN, hv.w, HEAD_DIM, proj);
        let s_src = &mut tape.s_src[h * m..(h + 1) * m];
        let s_dst = &mut tape.s_dst[h * m..(h + 1) * m];
        for ((ss, sd), prow) in s_src.iter_mut().zip(s_dst.iter_mut()).zip(proj.chunks_exact(HEAD_DIM)) {
            *ss = dot(prow, hv.a_src);
            *sd = dot(prow, hv.a_dst);
        }
        for i in 0..m {
            let (cols, _) = adj.row(i);
            // Pass 1: row max of leaky(s_src_i + s_dst_j) over the
            // neighborhood (always non-empty: self-loops).
            let mut zmax = f32::NEG_INFINITY;
            for &j in cols {
                zmax = zmax.max(leaky(s_src[i] + s_dst[j as usize]));
            }
            // Pass 2: exp weights + denom.
            row_w.clear();
            let mut z = 0.0f32;
            for &j in cols {
                let w = (leaky(s_src[i] + s_dst[j as usize]) - zmax).exp();
                row_w.push(w);
                z += w;
            }
            let denom = z.max(1e-12);
            // Pass 3: aggregate attn @ proj into this head's column block.
            let orow = &mut tape.out[i * HIDDEN + h * HEAD_DIM..i * HIDDEN + (h + 1) * HEAD_DIM];
            for (&j, &w) in cols.iter().zip(row_w.iter()) {
                let a = w / denom;
                let prow = &proj[j as usize * HEAD_DIM..(j as usize + 1) * HEAD_DIM];
                for (o, &pv) in orow.iter_mut().zip(prow) {
                    *o += a * pv;
                }
            }
        }
    }
    for x in tape.out.iter_mut() {
        if *x < 0.0 {
            *x = 0.0;
        }
    }
}

#[derive(Default)]
struct GatBwdScratch {
    d_pre: Vec<f32>,   // [m, HIDDEN]
    d_proj: Vec<f32>,  // [m, HEAD_DIM]
    d_s_src: Vec<f32>, // [m]
    d_s_dst: Vec<f32>, // [m]
    attn: Vec<f32>,    // one row
    avals: Vec<f32>,   // one row: d_agg · proj_j
}

#[derive(Default)]
struct BwdScratch {
    d_a: Vec<f32>,    // ping [n, HIDDEN]
    d_b: Vec<f32>,    // pong [n, HIDDEN]
    d_pool: Vec<f32>, // [k, HIDDEN]
    d_uvec: Vec<f32>, // [HIDDEN]
    gat: GatBwdScratch,
}

/// Backward of [`gat_forward`]: consumes `d_out [m, HIDDEN]`, accumulates
/// this layer's head-parameter gradients and writes `d_u [m, HIDDEN]`.
#[allow(clippy::too_many_arguments)]
fn gat_backward(
    p: &[f32],
    layer: usize,
    adj: &CsrAdjacency,
    u: &[f32],
    tape: &GatTape,
    d_out: &[f32],
    d_u: &mut Vec<f32>,
    grad: &mut [f32],
    sc: &mut GatBwdScratch,
) {
    let m = adj.n;
    // Relu gate: out > 0 ⟺ pre-activation > 0 (and grad 0 at exactly 0).
    fit(&mut sc.d_pre, m * HIDDEN);
    for ((dp, &o), &d) in sc.d_pre.iter_mut().zip(&tape.out).zip(d_out) {
        *dp = if o > 0.0 { d } else { 0.0 };
    }
    // Residual path.
    fit(d_u, m * HIDDEN);
    d_u.copy_from_slice(&sc.d_pre);
    for h in 0..HEADS {
        let hv = head_view(p, layer, h);
        let proj = &tape.proj[h * m * HEAD_DIM..(h + 1) * m * HEAD_DIM];
        let s_src = &tape.s_src[h * m..(h + 1) * m];
        let s_dst = &tape.s_dst[h * m..(h + 1) * m];
        fit(&mut sc.d_proj, m * HEAD_DIM);
        fit(&mut sc.d_s_src, m);
        fit(&mut sc.d_s_dst, m);
        for i in 0..m {
            let (cols, _) = adj.row(i);
            // Recompute the attention row (same arithmetic as forward).
            let mut zmax = f32::NEG_INFINITY;
            for &j in cols {
                zmax = zmax.max(leaky(s_src[i] + s_dst[j as usize]));
            }
            sc.attn.clear();
            let mut z = 0.0f32;
            for &j in cols {
                let w = (leaky(s_src[i] + s_dst[j as usize]) - zmax).exp();
                sc.attn.push(w);
                z += w;
            }
            let denom = z.max(1e-12);
            for a in sc.attn.iter_mut() {
                *a /= denom;
            }
            let d_agg = &sc.d_pre[i * HIDDEN + h * HEAD_DIM..i * HIDDEN + (h + 1) * HEAD_DIM];
            // aval_j = d_agg · proj_j; softmax backward needs Σ attn·aval.
            sc.avals.clear();
            let mut dot_i = 0.0f32;
            for (&j, &a) in cols.iter().zip(sc.attn.iter()) {
                let prow = &proj[j as usize * HEAD_DIM..(j as usize + 1) * HEAD_DIM];
                let av = dot(d_agg, prow);
                sc.avals.push(av);
                dot_i += a * av;
            }
            for ((&j, &a), &av) in cols.iter().zip(sc.attn.iter()).zip(sc.avals.iter()) {
                let j = j as usize;
                let d_e = a * (av - dot_i);
                let z_pre = s_src[i] + s_dst[j];
                let d_z = d_e * if z_pre >= 0.0 { 1.0 } else { LEAKY_SLOPE };
                sc.d_s_src[i] += d_z;
                sc.d_s_dst[j] += d_z;
                let dprow = &mut sc.d_proj[j * HEAD_DIM..(j + 1) * HEAD_DIM];
                for (dp, &da) in dprow.iter_mut().zip(d_agg) {
                    *dp += a * da;
                }
            }
        }
        // Score paths into proj and the attention vectors.
        let o = head_off(layer, h);
        for i in 0..m {
            let prow = &proj[i * HEAD_DIM..(i + 1) * HEAD_DIM];
            let dprow = &mut sc.d_proj[i * HEAD_DIM..(i + 1) * HEAD_DIM];
            let (dss, dsd) = (sc.d_s_src[i], sc.d_s_dst[i]);
            for ((dp, &asv), &adv) in dprow.iter_mut().zip(hv.a_src).zip(hv.a_dst) {
                *dp += dss * asv + dsd * adv;
            }
            let (ga, gd) = grad[o + HEAD_W_LEN..o + PER_HEAD].split_at_mut(HEAD_DIM);
            for ((g, gdv), &pv) in ga.iter_mut().zip(gd.iter_mut()).zip(prow) {
                *g += dss * pv;
                *gdv += dsd * pv;
            }
        }
        // d_w += uᵀ @ d_proj; d_u += d_proj @ wᵀ.
        matmul_t_acc(u, HIDDEN, &sc.d_proj, HEAD_DIM, &mut grad[o..o + HEAD_W_LEN]);
        matmul_bt_acc(&sc.d_proj, HEAD_DIM, hv.w, HIDDEN, d_u);
    }
}

// ---- native SAC learner ------------------------------------------------------

// Hyper-parameters (python/compile/sac.py verbatim; Table 2).
const ACTOR_LR: f32 = 1e-3;
const CRITIC_LR: f32 = 1e-3;
const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;
const ALPHA: f32 = 0.05;
pub const NOISE_CLIP: f32 = 0.3;

fn adam_step(x: &mut [f32], m: &mut [f32], v: &mut [f32], g: &[f32], t: f32, lr: f32) {
    let b1c = 1.0 - ADAM_B1.powf(t);
    let b2c = 1.0 - ADAM_B2.powf(t);
    for ((xi, (mi, vi)), &gi) in x.iter_mut().zip(m.iter_mut().zip(v.iter_mut())).zip(g) {
        *mi = ADAM_B1 * *mi + (1.0 - ADAM_B1) * gi;
        *vi = ADAM_B2 * *vi + (1.0 - ADAM_B2) * gi * gi;
        let mhat = *mi / b1c;
        let vhat = *vi / b2c;
        *xi -= lr * mhat / (vhat.sqrt() + ADAM_EPS);
    }
}

/// Pure-Rust SAC-discrete learner, drop-in peer of [`crate::rl::SacLearner`]
/// running against the native engine instead of an AOT artifact. Semantics
/// follow sac.py: single-step episodes (target = reward), twin-Q min, noisy
/// one-hot behavioral actions drawn in the exact AOT order, critic step then
/// actor step against the updated critic.
pub struct NativeSacLearner {
    engine: NativeEngine,
    actor: Vec<f32>,
    actor_m: Vec<f32>,
    actor_v: Vec<f32>,
    critic: Vec<f32>,
    critic_m: Vec<f32>,
    critic_v: Vec<f32>,
    t: u64,
    batch: usize,
    noise_clip: f32,
    act_scratch: Vec<f32>, // [batch, n, 2, 3] noisy one-hots
    rew_scratch: Vec<f32>,
    tape_a: TrunkTape,
    tape_q1: TrunkTape,
    tape_q2: TrunkTape,
    d_logits: Vec<f32>,
    grad: Vec<f32>,
    qmin: Vec<f32>,
    bwd: BwdScratch,
    pub last_metrics: SacMetrics,
    pub updates_done: u64,
}

impl NativeSacLearner {
    /// Build a learner sharing `engine`'s graph cache, starting from the
    /// given flat actor/critic parameters (so the trainer can hand the same
    /// actor vector to the EA population seed).
    pub fn new(
        engine: NativeEngine,
        batch: usize,
        actor: Vec<f32>,
        critic: Vec<f32>,
    ) -> anyhow::Result<NativeSacLearner> {
        anyhow::ensure!(batch > 0, "batch size must be positive");
        anyhow::ensure!(actor.len() == ACTOR_SIZE, "actor param length mismatch");
        anyhow::ensure!(critic.len() == CRITIC_SIZE, "critic param length mismatch");
        let n = engine.n();
        Ok(NativeSacLearner {
            actor_m: vec![0.0; ACTOR_SIZE],
            actor_v: vec![0.0; ACTOR_SIZE],
            critic_m: vec![0.0; CRITIC_SIZE],
            critic_v: vec![0.0; CRITIC_SIZE],
            actor,
            critic,
            t: 0,
            batch,
            noise_clip: NOISE_CLIP,
            act_scratch: vec![0.0; batch * n * OUT_DIM],
            rew_scratch: vec![0.0; batch],
            tape_a: TrunkTape::default(),
            tape_q1: TrunkTape::default(),
            tape_q2: TrunkTape::default(),
            d_logits: vec![0.0; n * OUT_DIM],
            grad: vec![0.0; ACTOR_SIZE],
            qmin: vec![0.0; n * OUT_DIM],
            bwd: BwdScratch::default(),
            engine,
            last_metrics: SacMetrics::default(),
            updates_done: 0,
        })
    }

    /// Current actor parameter vector (for rollouts and EA migration).
    pub fn actor_params(&self) -> &[f32] {
        &self.actor
    }

    /// Minibatch size expected by [`NativeSacLearner::update`].
    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// One full SAC gradient step (sac.py `sac_update` semantics).
    ///
    /// The graph state is identical across the batch, so per-choice Q and π
    /// are batch-independent and the batched loss gradients collapse onto a
    /// single per-graph `d_logits` tensor weighted by the batch residuals —
    /// 5 trunk forwards + 3 backwards total, independent of batch size.
    pub fn update(&mut self, minibatch: &[&Transition], rng: &mut Rng) -> anyhow::Result<SacMetrics> {
        anyhow::ensure!(minibatch.len() == self.batch, "minibatch must match learner batch");
        self.t += 1;
        let n = self.engine.n();
        let b = self.batch;
        let masked = (SUBACTIONS * n) as f32; // masked_mean denominator

        // Noisy one-hot behavioral actions — same RNG draw order as the AOT
        // learner (node-major, weight then activation, 3 choices each).
        self.act_scratch.iter_mut().for_each(|x| *x = 0.0);
        for (bi, tr) in minibatch.iter().enumerate() {
            debug_assert_eq!(tr.actions.len(), n);
            let base_b = bi * n * OUT_DIM;
            for (node, &[wa, aa]) in tr.actions.iter().enumerate() {
                for (k, a) in [wa, aa].into_iter().enumerate() {
                    let base = base_b + (node * 2 + k) * 3;
                    for c in 0..3 {
                        let onehot = if c == a as usize { 1.0 } else { 0.0 };
                        let noise =
                            clamp((rng.normal() as f32) * 0.1, -self.noise_clip, self.noise_clip);
                        self.act_scratch[base + c] = onehot + noise;
                    }
                }
            }
            self.rew_scratch[bi] = tr.reward;
        }

        // ---- critic step ----
        let (q1p, q2p) = self.critic.split_at(ACTOR_SIZE);
        self.engine.trunk_logits(q1p, &mut self.tape_q1);
        self.engine.trunk_logits(q2p, &mut self.tape_q2);
        let mut closs = 0.0f32;
        let mut mean_q = 0.0f32;
        // Per-sample residual coefficients feeding the collapsed gradient.
        let mut coef1 = vec![0.0f32; b];
        let mut coef2 = vec![0.0f32; b];
        for bi in 0..b {
            let act = &self.act_scratch[bi * n * OUT_DIM..(bi + 1) * n * OUT_DIM];
            let q1_pred = dot(act, &self.tape_q1.logits) / masked;
            let q2_pred = dot(act, &self.tape_q2.logits) / masked;
            let y = self.rew_scratch[bi];
            closs += (y - q1_pred).powi(2) + (y - q2_pred).powi(2);
            mean_q += q1_pred;
            coef1[bi] = -2.0 * (y - q1_pred) / (b as f32 * masked);
            coef2[bi] = -2.0 * (y - q2_pred) / (b as f32 * masked);
        }
        closs /= b as f32;
        mean_q /= b as f32;
        let t_f = self.t as f32;
        for (half, (tape, coef)) in [(0usize, (&self.tape_q1, &coef1)), (1, (&self.tape_q2, &coef2))]
        {
            self.d_logits.iter_mut().for_each(|x| *x = 0.0);
            for (bi, &c) in coef.iter().enumerate() {
                let act = &self.act_scratch[bi * n * OUT_DIM..(bi + 1) * n * OUT_DIM];
                for (d, &a) in self.d_logits.iter_mut().zip(act) {
                    *d += c * a;
                }
            }
            self.grad.iter_mut().for_each(|x| *x = 0.0);
            let range = half * ACTOR_SIZE..(half + 1) * ACTOR_SIZE;
            self.engine.trunk_backward(
                &self.critic[range.clone()],
                tape,
                &self.d_logits,
                &mut self.grad,
                &mut self.bwd,
            );
            adam_step(
                &mut self.critic[range.clone()],
                &mut self.critic_m[range.clone()],
                &mut self.critic_v[range],
                &self.grad,
                t_f,
                CRITIC_LR,
            );
        }

        // ---- actor step (against the updated critic) ----
        let (q1p, q2p) = self.critic.split_at(ACTOR_SIZE);
        self.engine.trunk_logits(q1p, &mut self.tape_q1);
        self.engine.trunk_logits(q2p, &mut self.tape_q2);
        for ((q, &a), &bq) in self
            .qmin
            .iter_mut()
            .zip(&self.tape_q1.logits)
            .zip(&self.tape_q2.logits)
        {
            *q = a.min(bq);
        }
        self.engine.trunk_logits(&self.actor, &mut self.tape_a);
        let mut aloss = 0.0f32;
        let mut entropy = 0.0f32;
        for (ltrip, (dtrip, qtrip)) in self
            .tape_a
            .logits
            .chunks_exact(CHOICES)
            .zip(self.d_logits.chunks_exact_mut(CHOICES).zip(self.qmin.chunks_exact(CHOICES)))
        {
            let m = ltrip.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0.0f32;
            let mut e = [0.0f32; CHOICES];
            for (ev, &l) in e.iter_mut().zip(ltrip) {
                *ev = (l - m).exp();
                z += *ev;
            }
            let lz = z.ln();
            let mut probs = [0.0f32; CHOICES];
            let mut logp = [0.0f32; CHOICES];
            for c in 0..CHOICES {
                probs[c] = e[c] / z;
                logp[c] = ltrip[c] - m - lz;
            }
            // f_c = α·logπ_c − qmin_c; inner = Σ π f; dlogit = π (f − inner)
            // (the α-entropy "+1" terms cancel because Σπ = 1).
            let mut inner = 0.0f32;
            let mut ent = 0.0f32;
            let mut f = [0.0f32; CHOICES];
            for c in 0..CHOICES {
                f[c] = ALPHA * logp[c] - qtrip[c];
                inner += probs[c] * f[c];
                ent -= probs[c] * logp[c];
            }
            aloss += inner;
            entropy += ent;
            for (d, (&pc, &fc)) in dtrip.iter_mut().zip(probs.iter().zip(f.iter())) {
                *d = pc * (fc - inner) / masked;
            }
        }
        aloss /= masked;
        entropy /= masked;
        self.grad.iter_mut().for_each(|x| *x = 0.0);
        self.engine
            .trunk_backward(&self.actor, &self.tape_a, &self.d_logits, &mut self.grad, &mut self.bwd);
        adam_step(&mut self.actor, &mut self.actor_m, &mut self.actor_v, &self.grad, t_f, ACTOR_LR);

        self.last_metrics = SacMetrics {
            critic_loss: closs,
            actor_loss: aloss,
            entropy,
            mean_q,
        };
        self.updates_done += 1;
        anyhow::ensure!(
            self.last_metrics.critic_loss.is_finite(),
            "SAC diverged: critic loss {}",
            self.last_metrics.critic_loss
        );
        Ok(self.last_metrics)
    }
}

// ---- dense reference oracle --------------------------------------------------

/// Literal dense transcription of model.py `policy_forward`, including the
/// AOT padding semantics (`NEG_INF` row masking, padded pool slots) and an
/// explicit pool size `k`. O(n²) per layer — test/bench oracle only.
pub fn dense_reference_probs(
    params: &[f32],
    feats: &[f32],
    adj: &[f32],
    mask: &[f32],
    n: usize,
    k: usize,
) -> Vec<f32> {
    assert_eq!(params.len(), ACTOR_SIZE);
    assert_eq!(feats.len(), n * FEATURE_DIM);
    assert_eq!(adj.len(), n * n);
    assert_eq!(mask.len(), n);
    const NEG_INF: f32 = -1e9;
    let gat = |layer: usize, h: &[f32], adj: &[f32], m: usize| -> Vec<f32> {
        let mut out = h.to_vec();
        for head in 0..HEADS {
            let hv = head_view(params, layer, head);
            let mut proj = vec![0.0f32; m * HEAD_DIM];
            matmul(h, HIDDEN, hv.w, HEAD_DIM, &mut proj);
            let s_src: Vec<f32> = proj.chunks_exact(HEAD_DIM).map(|r| dot(r, hv.a_src)).collect();
            let s_dst: Vec<f32> = proj.chunks_exact(HEAD_DIM).map(|r| dot(r, hv.a_dst)).collect();
            for i in 0..m {
                let arow = &adj[i * m..(i + 1) * m];
                let e: Vec<f32> = (0..m)
                    .map(|j| if arow[j] > 0.0 { leaky(s_src[i] + s_dst[j]) } else { NEG_INF })
                    .collect();
                let emax = e.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let w: Vec<f32> = e
                    .iter()
                    .zip(arow)
                    .map(|(&ev, &av)| if av > 0.0 { (ev - emax).exp() } else { 0.0 })
                    .collect();
                let denom = w.iter().sum::<f32>().max(1e-12);
                let orow = &mut out[i * HIDDEN + head * HEAD_DIM..i * HIDDEN + (head + 1) * HEAD_DIM];
                for (j, &wj) in w.iter().enumerate() {
                    let a = wj / denom;
                    for (o, &pv) in orow.iter_mut().zip(&proj[j * HEAD_DIM..(j + 1) * HEAD_DIM]) {
                        *o += a * pv;
                    }
                }
            }
        }
        for x in out.iter_mut() {
            if *x < 0.0 {
                *x = 0.0;
            }
        }
        out
    };

    // Input projection.
    let mut h = vec![0.0f32; n * HIDDEN];
    let xn: Vec<f32> = feats
        .chunks_exact(FEATURE_DIM)
        .flat_map(|row| row.iter().zip(&FEATURE_SCALE).map(|(&x, &s)| x / s))
        .collect();
    matmul(&xn, FEATURE_DIM, &params[W_IN_OFF..W_IN_OFF + W_IN_LEN], HIDDEN, &mut h);
    for (row, &mk) in h.chunks_exact_mut(HIDDEN).zip(mask) {
        for (x, &bv) in row.iter_mut().zip(&params[B_IN_OFF..B_IN_OFF + HIDDEN]) {
            *x = (*x + bv).tanh() * mk;
        }
    }
    let h1 = gat(0, &h, adj, n);
    // Pooling: rank by pairwise comparison, one-hot selection.
    let pool_p = &params[POOL_P_OFF..POOL_P_OFF + HIDDEN];
    let norm = dot(pool_p, pool_p).sqrt();
    let uvec: Vec<f32> = pool_p.iter().map(|&x| x / (norm + 1e-8)).collect();
    let scores: Vec<f32> = h1
        .chunks_exact(HIDDEN)
        .zip(mask)
        .map(|(row, &mk)| if mk > 0.0 { dot(row, &uvec) } else { NEG_INF })
        .collect();
    let rank: Vec<usize> = (0..n)
        .map(|i| {
            (0..n)
                .filter(|&j| scores[j] > scores[i] || (scores[j] == scores[i] && j < i))
                .count()
        })
        .collect();
    let mut sel = vec![usize::MAX; k]; // sel[r] = node with rank r
    for (i, &r) in rank.iter().enumerate() {
        if r < k {
            sel[r] = i;
        }
    }
    let gate: Vec<f32> = scores
        .iter()
        .zip(mask)
        .map(|(&s, &mk)| sigmoid(s) * mk)
        .collect();
    let mut hp = vec![0.0f32; k * HIDDEN];
    let mut adj_p = vec![0.0f32; k * k];
    for r in 0..k {
        let i = sel[r];
        for c in 0..HIDDEN {
            hp[r * HIDDEN + c] = h1[i * HIDDEN + c] * gate[i];
        }
        for (r2, &i2) in sel.iter().enumerate() {
            adj_p[r * k + r2] = adj[i * n + i2];
        }
    }
    let h2 = gat(1, &hp, &adj_p, k);
    // Unpool + skip.
    let mut h_up = h1.clone();
    for (r, row) in h2.chunks_exact(HIDDEN).enumerate() {
        let i = sel[r];
        for (x, &v) in h_up[i * HIDDEN..(i + 1) * HIDDEN].iter_mut().zip(row) {
            *x += v;
        }
    }
    let h3 = gat(2, &h_up, adj, n);
    let mut h4 = gat(3, &h3, adj, n);
    for (row, &mk) in h4.chunks_exact_mut(HIDDEN).zip(mask) {
        for x in row.iter_mut() {
            *x *= mk;
        }
    }
    // Head + softmax.
    let mut logits = vec![0.0f32; n * OUT_DIM];
    matmul(&h4, HIDDEN, &params[W_OUT_OFF..W_OUT_OFF + W_OUT_LEN], OUT_DIM, &mut logits);
    let mut probs = vec![0.0f32; n * OUT_DIM];
    for (lrow, prow) in logits.chunks_exact_mut(OUT_DIM).zip(probs.chunks_exact_mut(OUT_DIM)) {
        for (x, &bv) in lrow.iter_mut().zip(&params[B_OUT_OFF..B_OUT_OFF + OUT_DIM]) {
            *x += bv;
        }
        for (ltrip, ptrip) in lrow.chunks_exact(CHOICES).zip(prow.chunks_exact_mut(CHOICES)) {
            let m = ltrip.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0.0f32;
            for (pv, &l) in ptrip.iter_mut().zip(ltrip) {
                *pv = (l - m).exp();
                z += *pv;
            }
            for pv in ptrip.iter_mut() {
                *pv /= z;
            }
        }
    }
    probs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::features;
    use crate::workloads::synthetic::{synthetic, SyntheticConfig};

    fn test_graph(nodes: usize, seed: u64) -> Graph {
        let cfg = SyntheticConfig { nodes, ..Default::default() };
        synthetic(&cfg, &mut Rng::new(seed))
    }

    #[test]
    fn layout_matches_manifest_sizes() {
        // model.py: ACTOR_SIZE = 18630, CRITIC_SIZE = 37260 (manifest.json).
        assert_eq!(ACTOR_SIZE, 18630);
        assert_eq!(CRITIC_SIZE, 37260);
        assert_eq!(HEADS * HEAD_DIM, HIDDEN);
    }

    #[test]
    fn probs_rows_are_distributions() {
        for &(n, seed) in &[(2usize, 4u64), (3, 5), (17, 6), (40, 7)] {
            let g = test_graph(n, seed);
            let engine = NativeEngine::for_graph(&g);
            let params = init_actor_params(&mut Rng::new(seed));
            let probs = engine.probs(&params).unwrap();
            assert_eq!(probs.len(), g.len() * OUT_DIM);
            for trip in probs.chunks_exact(CHOICES) {
                let z: f32 = trip.iter().sum();
                assert!((z - 1.0).abs() < 1e-5, "row sums to {z}");
                assert!(trip.iter().all(|&p| (0.0..=1.0).contains(&p)));
            }
        }
    }

    #[test]
    fn dram_biased_init_prefers_dram() {
        let g = test_graph(30, 11);
        let engine = NativeEngine::for_graph(&g);
        let params = init_actor_params(&mut Rng::new(11));
        let probs = engine.probs(&params).unwrap();
        let dram_wins = probs
            .chunks_exact(CHOICES)
            .filter(|t| crate::utils::math::argmax(t) == 0)
            .count();
        let total = probs.len() / CHOICES;
        assert!(
            dram_wins * 10 >= total * 9,
            "DRAM argmax on {dram_wins}/{total} decisions"
        );
    }

    #[test]
    fn sparse_matches_dense_reference() {
        use crate::testing::prop::check;
        check(
            "native sparse forward == dense model.py reference",
            12,
            |gg| {
                let n = gg.usize_in(4, 24);
                let seed = gg.rng().next_u64();
                ((n, seed), ())
            },
            |&(n, seed), _| {
                let g = test_graph(n, seed);
                let n = g.len();
                let engine = NativeEngine::for_graph(&g);
                let params = init_actor_params(&mut Rng::new(seed ^ 0xA5));
                let sparse = engine.probs(&params).unwrap();
                let dense = dense_reference_probs(
                    &params,
                    &features::padded_feature_matrix(&g, n),
                    &g.normalized_adjacency(n),
                    &g.node_mask(n),
                    n,
                    pool_k(n),
                );
                sparse
                    .iter()
                    .zip(&dense)
                    .all(|(&a, &b)| (a - b).abs() < 1e-4)
            },
        );
    }

    #[test]
    fn padding_never_affects_actions() {
        // Dense forwards padded to several sizes, with the pool size pinned
        // to pool_k(n_real), must agree on the real rows — and match the
        // native sparse forward (satellite: padding invariance).
        let g = test_graph(13, 21);
        let n = g.len();
        let k = pool_k(n);
        let params = init_actor_params(&mut Rng::new(21));
        let native = NativeEngine::for_graph(&g).probs(&params).unwrap();
        for n_max in [n, n + 5, 2 * n + 3] {
            let dense = dense_reference_probs(
                &params,
                &features::padded_feature_matrix(&g, n_max),
                &g.normalized_adjacency(n_max),
                &g.node_mask(n_max),
                n_max,
                k,
            );
            for (i, (&a, &b)) in native.iter().zip(&dense[..n * OUT_DIM]).enumerate() {
                assert!(
                    (a - b).abs() < 1e-4,
                    "padded n_max={n_max} diverges at {i}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn backward_matches_finite_differences() {
        // Scalar test loss L = Σ c_ij · probs_ij with fixed coefficients;
        // analytic gradient via softmax backward + trunk_backward, checked
        // against central differences on coordinates sampled from every
        // parameter region. Seeds are scanned for a well-separated pooling
        // score gap so the (deliberately non-differentiable) top-k selection
        // cannot flip inside the finite-difference stencil.
        let g = test_graph(8, 2);
        let n = g.len();
        let engine = NativeEngine::for_graph(&g); // k = 2 at n = 8
        let mut params = Vec::new();
        let mut seed_ok = false;
        for s in 0..24u64 {
            params = init_actor_params(&mut Rng::new(1000 + s));
            let mut ws = NativeWorkspace::default();
            engine.probs_into(&params, &mut ws);
            let mut sc: Vec<f32> = ws.tape.scores.clone();
            sc.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let min_gap = sc.windows(2).map(|w| w[0] - w[1]).fold(f32::INFINITY, f32::min);
            if min_gap > 2e-2 {
                seed_ok = true;
                break;
            }
        }
        assert!(seed_ok, "no init seed with separated pooling scores");

        let coeff = |i: usize| ((i * 2654435761) % 17) as f32 / 8.0 - 1.0;
        let loss = |params: &[f32]| -> f32 {
            let mut ws = NativeWorkspace::default();
            let probs = NativeEngine::for_graph(&g).probs_into(params, &mut ws);
            probs.iter().enumerate().map(|(i, &p)| coeff(i) * p).sum()
        };

        // Analytic gradient.
        let mut ws = NativeWorkspace::default();
        engine.probs_into(&params, &mut ws);
        let mut d_logits = vec![0.0f32; n * OUT_DIM];
        for (t, (ptrip, dtrip)) in ws
            .tape
            .probs
            .chunks_exact(CHOICES)
            .zip(d_logits.chunks_exact_mut(CHOICES))
            .enumerate()
        {
            let c: Vec<f32> = (0..CHOICES).map(|j| coeff(t * CHOICES + j)).collect();
            let pc = dot(ptrip, &c);
            for ((d, &p), &cv) in dtrip.iter_mut().zip(ptrip).zip(&c) {
                *d = p * (cv - pc);
            }
        }
        let mut grad = vec![0.0f32; ACTOR_SIZE];
        let mut bwd = BwdScratch::default();
        engine.trunk_backward(&params, &ws.tape, &d_logits, &mut grad, &mut bwd);

        // Sample coordinates from every region of the layout.
        let mut coords = vec![
            W_IN_OFF,
            W_IN_OFF + 37,
            B_IN_OFF + 3,
            POOL_P_OFF + 1,
            POOL_P_OFF + 40,
            W_OUT_OFF + 5,
            B_OUT_OFF + 2,
        ];
        for layer in 0..NUM_LAYERS {
            let o = head_off(layer, layer % HEADS);
            coords.push(o + 11); // w
            coords.push(o + HEAD_W_LEN + 2); // a_src
            coords.push(o + HEAD_W_LEN + HEAD_DIM + 5); // a_dst
        }
        let h = 1e-3f32;
        for &ci in &coords {
            let mut pp = params.clone();
            pp[ci] += h;
            let lp = loss(&pp);
            pp[ci] = params[ci] - h;
            let lm = loss(&pp);
            let fd = (lp - lm) / (2.0 * h);
            let an = grad[ci];
            let tol = 0.08 * an.abs().max(fd.abs()) + 3e-3;
            assert!(
                (an - fd).abs() <= tol,
                "grad mismatch at {ci}: analytic {an} vs fd {fd}"
            );
        }
    }

    #[test]
    fn sac_update_learns_constant_reward() {
        let g = test_graph(12, 31);
        let n = g.len();
        let engine = NativeEngine::for_graph(&g);
        let mut rng = Rng::new(31);
        let actor = init_actor_params(&mut rng);
        let critic = init_critic_params(&mut rng);
        let batch = 6;
        let mut learner = NativeSacLearner::new(engine, batch, actor.clone(), critic).unwrap();
        let trs: Vec<Transition> = (0..batch)
            .map(|i| Transition {
                actions: (0..n).map(|j| [((i + j) % 3) as u8, (j % 3) as u8]).collect(),
                reward: 0.5,
            })
            .collect();
        let batch_refs: Vec<&Transition> = trs.iter().collect();
        let first = learner.update(&batch_refs, &mut rng).unwrap();
        for _ in 0..40 {
            learner.update(&batch_refs, &mut rng).unwrap();
        }
        let last = learner.last_metrics;
        assert!(first.critic_loss.is_finite() && last.critic_loss.is_finite());
        assert!(
            last.critic_loss < first.critic_loss,
            "critic loss did not decrease: {} -> {}",
            first.critic_loss,
            last.critic_loss
        );
        assert!(learner.actor_params() != actor.as_slice(), "actor never moved");
        assert_eq!(learner.updates_done, 41);
    }

    #[test]
    fn rejects_bad_parameter_lengths() {
        let g = test_graph(6, 41);
        let engine = NativeEngine::for_graph(&g);
        assert!(engine.probs(&[0.0; 10]).is_err());
        let e2 = NativeEngine::for_graph(&g);
        assert!(NativeSacLearner::new(e2, 4, vec![0.0; 3], vec![0.0; CRITIC_SIZE]).is_err());
    }

    #[test]
    fn pool_k_override_clamps() {
        let g = test_graph(9, 51);
        let engine = NativeEngine::for_graph(&g).with_pool_k(500);
        assert_eq!(engine.pool_size(), g.len());
        let engine = NativeEngine::for_graph(&g).with_pool_k(0);
        assert_eq!(engine.pool_size(), 1);
        // Forward still valid at extreme pool sizes.
        let params = init_actor_params(&mut Rng::new(51));
        for trip in engine.probs(&params).unwrap().chunks_exact(CHOICES) {
            assert!((trip.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        }
    }
}
