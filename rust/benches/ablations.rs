//! Ablation benchmarks for the design choices DESIGN.md calls out.
//!
//! Artifact-free set (always runs):
//!   * invalid-mapping feedback: ε-proportional penalty vs no signal —
//!     the paper's implicit-validity-learning mechanism (§3.1 Reward);
//!   * population size (Table 2 explored 10 vs 20);
//!   * measurement noise robustness (the "noisy feedback" claim);
//!   * elite count.
//!
//! With artifacts present, additionally:
//!   * Boltzmann fraction {0.0, 0.2, 0.5} of the mixed population
//!     (Table 2 explored exactly these) under EA evolution.

use std::sync::Arc;

use egrl::bench_harness::{pm, Table};
use egrl::config::EgrlConfig;
use egrl::coordinator::{Mode, Trainer};
use egrl::env::{EnvConfig, MappingEnv};
use egrl::metrics::{RunLog, SeedAggregate};
use egrl::runtime::Runtime;
use egrl::sim::spec::ChipSpec;
use egrl::workloads::Workload;

fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn run_ea(cfg: &EgrlConfig, env_cfg: EnvConfig, seeds: u64, rt: Option<&Runtime>) -> SeedAggregate {
    let runs: Vec<RunLog> = (0..seeds)
        .map(|s| {
            let mut c = cfg.clone();
            c.seed = s;
            let env = Arc::new(MappingEnv::new(
                Workload::ResNet50.build(),
                ChipSpec::nnpi(),
                env_cfg.clone(),
                s,
            ));
            let mut t = Trainer::new(env, c, Mode::EaOnly, rt).unwrap();
            let mut log = RunLog::new("resnet50", "ea", s);
            t.run(&mut log).unwrap();
            log
        })
        .collect();
    SeedAggregate::from_runs(&runs)
}

fn main() -> anyhow::Result<()> {
    let steps = env_u64("EGRL_BENCH_STEPS", 600);
    let seeds = env_u64("EGRL_BENCH_SEEDS", 3);
    let base = EgrlConfig { total_steps: steps, ..Default::default() };
    let mut table = Table::new(&["ablation", "setting", "final speedup", "seeds"]);

    // --- invalid-mapping feedback ------------------------------------------
    for (label, scale) in [("-ε penalty (paper)", 1.0), ("no signal (r=0)", 0.0)] {
        let mut env_cfg = base.env_config();
        env_cfg.invalid_scale = scale;
        let agg = run_ea(&base, env_cfg, seeds, None);
        table.row(&[
            "invalid-map reward".into(),
            label.into(),
            pm(agg.summary.mean, agg.summary.std),
            seeds.to_string(),
        ]);
    }

    // --- population size ------------------------------------------------------
    for pop in [10usize, 20] {
        let cfg = EgrlConfig { pop_size: pop, elites: pop / 5, ..base.clone() };
        let agg = run_ea(&cfg, base.env_config(), seeds, None);
        table.row(&[
            "population size".into(),
            pop.to_string(),
            pm(agg.summary.mean, agg.summary.std),
            seeds.to_string(),
        ]);
    }

    // --- measurement-noise robustness ----------------------------------------
    for noise in [0.0, 0.02, 0.10] {
        let mut env_cfg = base.env_config();
        env_cfg.noise_std = noise;
        let agg = run_ea(&base, env_cfg, seeds, None);
        table.row(&[
            "latency noise σ".into(),
            format!("{noise}"),
            pm(agg.summary.mean, agg.summary.std),
            seeds.to_string(),
        ]);
    }

    // --- elites -----------------------------------------------------------------
    for elites in [1usize, 4, 8] {
        let cfg = EgrlConfig { elites, ..base.clone() };
        let agg = run_ea(&cfg, base.env_config(), seeds, None);
        table.row(&[
            "elite count".into(),
            elites.to_string(),
            pm(agg.summary.mean, agg.summary.std),
            seeds.to_string(),
        ]);
    }

    // --- Boltzmann fraction (mixed population; needs artifacts) ---------------
    let dir = Runtime::default_dir();
    if dir.join("manifest.json").exists() {
        let rt = Runtime::open(dir)?;
        for frac in [0.0, 0.2, 0.5] {
            let cfg = EgrlConfig {
                boltzmann_fraction: frac,
                total_steps: steps.min(400),
                ..base.clone()
            };
            let agg = run_ea(&cfg, base.env_config(), seeds.min(2), Some(&rt));
            table.row(&[
                "boltzmann fraction".into(),
                format!("{frac}"),
                pm(agg.summary.mean, agg.summary.std),
                seeds.min(2).to_string(),
            ]);
        }
    } else {
        println!("(boltzmann-fraction ablation skipped: artifacts missing)");
    }

    println!("\n=== Ablations (ResNet-50, {steps} iterations) ===\n");
    table.print();
    println!(
        "\nexpected: the -ε penalty beats the no-signal ablation (validity is \
         learnable from the feedback); performance degrades gracefully with \
         noise; pop 20 ≈ pop 10 at equal iteration budgets."
    );
    Ok(())
}
