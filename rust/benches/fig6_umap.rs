//! Figure 6 reproduction: separability of compiler-competitive vs best
//! mappings in mapping space (Jaccard metric over one-hot encodings).
//!
//! The paper shows a UMAP scatter; offline we compute the same distance
//! structure and report (a) a classical-MDS 2-D embedding summary and
//! (b) the silhouette coefficient — a quantitative version of the
//! figure's separability claim — plus where the compiler's own map falls
//! (the paper's red arrow: inside the competitive cluster).

use std::sync::Arc;

use egrl::bench_harness::Table;
use egrl::config::EgrlConfig;
use egrl::coordinator::{Mode, Trainer};
use egrl::env::MappingEnv;
use egrl::mapping::MemoryMap;
use egrl::metrics::RunLog;
use egrl::runtime::Runtime;
use egrl::utils::Rng;
use egrl::viz::embed;
use egrl::workloads::Workload;

fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let steps = env_u64("EGRL_BENCH_STEPS", 1500);
    let runtime = {
        let dir = Runtime::default_dir();
        if dir.join("manifest.json").exists() { Some(Runtime::open(dir)?) } else { None }
    };
    let mut table = Table::new(&[
        "workload",
        "competitive",
        "best",
        "silhouette",
        "compiler→competitive d̄",
        "compiler→best d̄",
    ]);

    for w in Workload::all() {
        let env = Arc::new(MappingEnv::nnpi(w.build(), 21));
        let cfg = EgrlConfig { seed: 21, total_steps: steps, ..Default::default() };
        let mut trainer = Trainer::new(env.clone(), cfg, Mode::EaOnly, runtime.as_ref())?;
        let mut rng = Rng::new(210);
        // Snapshot the running best each generation; label post hoc so
        // the "best" phase adapts to how far this run actually got
        // (the paper's two phases are ~1.0 and the run's peak).
        let mut snaps: Vec<(MemoryMap, f64)> = Vec::new();
        while env.iterations() < steps {
            trainer.generation()?;
            let map = trainer.best_map().clone();
            let s = env.eval_speedup(&map, &mut rng);
            snaps.push((map, s));
        }
        let mut log = RunLog::new(w.name(), "ea", 21);
        let _ = trainer.run(&mut log);
        let peak = snaps.iter().map(|(_, s)| *s).fold(0.0, f64::max);
        let mut competitive: Vec<MemoryMap> = Vec::new();
        let mut best: Vec<MemoryMap> = Vec::new();
        for (map, s) in snaps {
            if (s - 1.0).abs() <= 0.04 && competitive.len() < 20 {
                competitive.push(map);
            } else if s >= (peak - 0.015).max(1.015) && best.len() < 20 {
                best.push(map);
            }
        }
        if competitive.len() < 4 || best.len() < 4 {
            table.row(&[
                w.name().into(),
                competitive.len().to_string(),
                best.len().to_string(),
                "n/a (too few snapshots)".into(),
                "-".into(),
                "-".into(),
            ]);
            continue;
        }

        let mut maps = competitive.clone();
        maps.extend(best.iter().cloned());
        let n = maps.len();
        let labels: Vec<usize> = (0..n).map(|i| usize::from(i >= competitive.len())).collect();
        let d = embed::distance_matrix(&maps);
        let sil = embed::silhouette(&d, n, &labels);
        // MDS exists mostly for plotting; compute it to exercise the path.
        let _coords = embed::mds_2d(&d, n);

        // Compiler map's mean Jaccard distance to each phase — the red
        // arrow lands in the competitive cluster iff d̄_comp < d̄_best.
        let cmap = &env.compiler_map;
        let mean_d = |phase: &[MemoryMap]| -> f64 {
            phase.iter().map(|m| cmap.jaccard_distance(m)).sum::<f64>() / phase.len() as f64
        };
        table.row(&[
            w.name().into(),
            competitive.len().to_string(),
            best.len().to_string(),
            format!("{sil:.3}"),
            format!("{:.3}", mean_d(&competitive)),
            format!("{:.3}", mean_d(&best)),
        ]);
    }

    println!("\n=== Figure 6: mapping-space separability (Jaccard metric) ===\n");
    table.print();
    println!(
        "\npaper claims to check: silhouette > 0 (phases separable) and \
         compiler→competitive d̄ < compiler→best d̄ (the compiler's map \
         falls in the competitive cluster)."
    );
    Ok(())
}
