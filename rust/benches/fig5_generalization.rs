//! Figure 5 reproduction: zero-shot generalization of the GNN policy.
//!
//! Trains the GNN policy on one workload, periodically evaluating the
//! best GNN genome — unchanged — on the other two workloads. One flat
//! parameter vector drives every graph-size artifact variant, which is
//! exactly the Fig-5 transfer mechanism.
//!
//! Default mode evolves the GNN by EA only (policy_fwd artifacts compile
//! in seconds; the SAC artifact takes minutes of XLA compile on this
//! image). `EGRL_BENCH_FULL=1` switches to full EGRL, matching the paper.
//!
//! Requires `make artifacts`.

use std::sync::Arc;

use egrl::bench_harness::Table;
use egrl::config::EgrlConfig;
use egrl::coordinator::{Mode, Trainer};
use egrl::ea::Genome;
use egrl::env::MappingEnv;
use egrl::gnn::PolicyRunner;
use egrl::metrics::RunLog;
use egrl::runtime::Runtime;
use egrl::utils::Rng;
use egrl::workloads::Workload;

fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Best GNN genome in the trainer (EA population first, PG actor as
/// fallback) — the policy Fig-5 transfers.
fn best_gnn_params(t: &Trainer) -> Option<Vec<f32>> {
    let pop = t.population();
    let mut best: Option<(&[f32], f64)> = None;
    for m in &pop.members {
        if let Genome::Gnn(g) = &m.genome {
            if best.map(|(_, f)| m.fitness > f).unwrap_or(true) {
                best = Some((g, m.fitness));
            }
        }
    }
    best.map(|(g, _)| g.to_vec())
        .or_else(|| t.pg_actor_params().map(|p| p.to_vec()))
}

fn main() -> anyhow::Result<()> {
    let dir = Runtime::default_dir();
    if !dir.join("manifest.json").exists() {
        println!("fig5: artifacts missing — run `make artifacts` first; skipping");
        return Ok(());
    }
    let rt = Runtime::open(dir)?;
    let steps = env_u64("EGRL_BENCH_STEPS", 400);
    let full = std::env::var("EGRL_BENCH_FULL").is_ok();
    let mode = if full { Mode::Egrl } else { Mode::EaOnly };

    let mut table = Table::new(&[
        "trained on", "iterations", "eval r50", "eval r101", "eval bert",
    ]);

    // The paper trains on BERT and on ResNet-50 (Fig. 5 panels).
    for source in [Workload::ResNet50, Workload::Bert] {
        let env = Arc::new(MappingEnv::nnpi(source.build(), 11));
        let cfg = EgrlConfig {
            seed: 11,
            total_steps: steps,
            update_every: if source == Workload::Bert { 84 } else { 21 },
            ..Default::default()
        };
        let mut trainer = Trainer::new(env, cfg, mode, Some(&rt))?;
        let mut log = RunLog::new(source.name(), mode.name(), 11);
        // Periodic checkpoints: thirds of the budget.
        let mut rows: Vec<Vec<String>> = Vec::new();
        for phase in 1..=3u64 {
            while trainer.env.iterations() < steps * phase / 3 {
                trainer.generation()?;
            }
            let Some(params) = best_gnn_params(&trainer) else { continue };
            let mut cells = vec![
                format!("{} (phase {phase}/3)", source.name()),
                trainer.env.iterations().to_string(),
            ];
            let mut rng = Rng::new(1000 + phase);
            for target in Workload::all() {
                let tenv = MappingEnv::nnpi(target.build(), 99);
                let runner = PolicyRunner::for_env(&rt, &tenv)?;
                let probs = runner.probs(&params)?;
                let map = runner.greedy_map(&probs);
                let s = tenv.eval_speedup(&map, &mut rng);
                let marker = if target == source { "*" } else { "" };
                cells.push(format!("{s:.3}{marker}"));
            }
            rows.push(cells);
        }
        let _ = trainer.run(&mut log); // drain any remaining budget
        for r in rows {
            table.row(&r);
        }
    }

    println!("\n=== Figure 5: zero-shot transfer (no fine-tuning; * = training task) ===\n");
    table.print();
    println!(
        "\npaper claim: 'decent zero-shot transfer' — expect off-diagonal entries \
         well above the ~0 of an untrained/random policy, trending with training."
    );
    Ok(())
}
