//! serve_bench: load generator for the placement-serving subsystem
//! (ISSUE 4 tentpole acceptance).
//!
//! Replays a Zipf-distributed `map` request mix over the paper set +
//! `synthetic-large` against an in-process [`Broker`], timing every
//! request, then drives the anytime refinement of the hottest workload
//! through chunked `polish` requests and reads back the published
//! improvement curve. Writes `BENCH_serve.json`
//! (`schema: egrl-bench-serve-v1`, uploaded by CI) with throughput,
//! p50/p99 latency split hit vs. cold, hit rate and the anytime curve.
//!
//! Acceptance targets checked here (reported as booleans, like every
//! other bench in this repo):
//! * cache-hit p99 ≥ **100×** faster than the mean cold (miss) path;
//! * the anytime curve is monotone **non-increasing** in latency —
//!   background publication never regresses a served map;
//! * the **multi-client TCP sweep** (ISSUE 5) shows throughput
//!   increasing with client count (thread-per-connection scale-out);
//! * an evicted-then-requested fingerprint is served from the **spill
//!   tier** without re-running the cold search path;
//! * the **multi-broker topology sweep** (ISSUE 10) replays a fixed
//!   client pool against 1–3 fingerprint-sharded proxying brokers over
//!   one shared spill tier and uploads the aggregate throughput curve
//!   (`multi_broker`); on a single machine the fleet must retain at
//!   least half the single-broker rate.
//!
//! Background workers are disabled (`workers: 0`) so the replay is
//! deterministic; the curve is produced by the same refinement engine
//! the workers run, driven synchronously via `polish`.

use std::io::{BufRead, BufReader, Write as _};
use std::net::{TcpListener, TcpStream};
use std::time::Instant;

use egrl::env::EnvConfig;
use egrl::obs::Histogram;
use egrl::serve::{Broker, ServeOptions};
use egrl::utils::json::{parse, Json};
use egrl::utils::Rng;
use egrl::workloads::Workload;

/// Latency summary from an O(1)-per-record log₂ histogram (the same
/// `obs::Histogram` the broker's `metrics` op serves) — replaces the
/// sort-the-whole-sample percentile pass. The mean is exact (from the
/// nanosecond sum); p50/p99 are bucket-interpolated, property-tested
/// against sorted-sample quantiles in `obs::hist`. Returns
/// `(json, mean_s, p99_s)`.
fn summary(label: &str, h: &Histogram) -> (Json, f64, f64) {
    let mean = if h.count() == 0 { f64::NAN } else { h.mean_ns() / 1e9 };
    let p50 = h.quantile_ns(0.50) / 1e9;
    let p99 = h.quantile_ns(0.99) / 1e9;
    println!(
        "  {label:<6} n={:<4} mean {:>9.1} µs   p50 {:>9.1} µs   p99 {:>9.1} µs",
        h.count(),
        mean * 1e6,
        p50 * 1e6,
        p99 * 1e6
    );
    let json = Json::obj(vec![
        ("count", Json::Num(h.count() as f64)),
        ("mean_us", Json::Num(mean * 1e6)),
        ("p50_us", Json::Num(p50 * 1e6)),
        ("p99_us", Json::Num(p99 * 1e6)),
    ]);
    (json, mean, p99)
}

fn main() -> anyhow::Result<()> {
    println!("== bench: serve_bench — Zipf replay against the placement broker ==");
    // Zipf(s = 1) over rank: resnet50 is the hot head, the 10k-node
    // scaling workload the cold tail.
    let mix =
        [Workload::ResNet50, Workload::Bert, Workload::ResNet101, Workload::SyntheticLarge];
    let zipf: Vec<f64> = (1..=mix.len()).map(|k| 1.0 / k as f64).collect();
    let zipf_total: f64 = zipf.iter().sum();

    let broker = Broker::new(ServeOptions {
        cache_cap: 16,
        deadline_ms: 10,
        refine_budget: 36_000,
        workers: 0,
        seed: 1,
        spill_dir: None,
        priority_refine: true,
        max_connections: 0,
        queue_depth: 0,
        spill_max_bytes: 0,
        trace_path: None,
        env: EnvConfig::default(),
        ..ServeOptions::default()
    });

    const REQUESTS: usize = 400;
    let mut rng = Rng::new(42);
    let mut hit_h = Histogram::new();
    let mut cold_h = Histogram::new();
    let replay_t0 = Instant::now();
    for _ in 0..REQUESTS {
        let mut x = rng.uniform() * zipf_total;
        let mut pick = mix[mix.len() - 1];
        for (&w, &weight) in mix.iter().zip(&zipf) {
            if x < weight {
                pick = w;
                break;
            }
            x -= weight;
        }
        let line = format!(r#"{{"op":"map","workload":"{}"}}"#, pick.name());
        let t0 = Instant::now();
        let resp = broker.handle(&line);
        let dt = t0.elapsed();
        let j = parse(&resp)?;
        match j.get("cache").and_then(Json::as_str) {
            Some("hit") => hit_h.record(dt),
            Some("miss") => cold_h.record(dt),
            _ => anyhow::bail!("unexpected serve response: {resp}"),
        }
    }
    let wall_s = replay_t0.elapsed().as_secs_f64();
    let throughput_rps = REQUESTS as f64 / wall_s;
    println!("\nreplayed {REQUESTS} requests in {wall_s:.3} s ({throughput_rps:.0} req/s)");
    let (hit_json, _hit_mean, hit_p99) = summary("hit", &hit_h);
    let (cold_json, cold_mean, _cold_p99) = summary("cold", &cold_h);
    let hit_rate = hit_h.count() as f64 / REQUESTS as f64;
    println!("  hit rate {:.3}", hit_rate);

    // Acceptance: cache-hit p99 ≥ 100× faster than cold mapping.
    let cold_over_hit_p99 = cold_mean / hit_p99;
    let latency_target_met = cold_over_hit_p99 >= 100.0;
    println!("  cold mean / hit p99 = {cold_over_hit_p99:.0}x (target >= 100x)");

    // Anytime-improvement curve: refine the hot workload through the
    // same engine the background workers run, publishing through the
    // monotone cache rule, then read the curve back.
    for _ in 0..8 {
        let resp = broker.handle(r#"{"op":"polish","workload":"resnet50","budget":4500}"#);
        anyhow::ensure!(parse(&resp)?.get("error").is_none(), "polish failed: {resp}");
    }
    let fp = broker.fingerprint_of(Workload::ResNet50);
    let curve = broker.cache().curve(fp);
    let curve_monotone = curve
        .windows(2)
        .all(|pair| pair[1].1 <= pair[0].1 && pair[1].0 >= pair[0].0);
    let final_entry = broker.cache().peek(fp).expect("hot entry resident");
    println!(
        "  anytime curve: {} publishes, latency {:.1} µs -> {:.1} µs (speedup {:.3}), monotone: {curve_monotone}",
        curve.len(),
        curve.first().map(|p| p.1 * 1e6).unwrap_or(f64::NAN),
        curve.last().map(|p| p.1 * 1e6).unwrap_or(f64::NAN),
        final_entry.speedup
    );

    let stats_line = broker.handle(r#"{"op":"stats"}"#);
    let stats = parse(&stats_line)?;

    // ---- multi-client TCP sweep (ISSUE 5 tentpole acceptance) ----------
    // Fresh broker per client count (identical pre-warmed cache state),
    // thread-per-connection server, every client replaying the same hot
    // request mix with `return_map` (the serialization work happens
    // outside every lock, which is what the thread-per-conn design
    // parallelizes).
    println!("\n== multi-client TCP sweep ==");
    let hot_mix = [Workload::ResNet50, Workload::Bert, Workload::ResNet101];
    const PER_CLIENT: usize = 150;
    let sweep = [1usize, 2, 4, 8];
    let mut sweep_rows: Vec<Json> = Vec::new();
    let mut sweep_rps: Vec<f64> = Vec::new();
    for &clients in &sweep {
        let b = Broker::new(ServeOptions {
            cache_cap: 16,
            deadline_ms: 0,
            refine_budget: 36_000,
            workers: 0,
            seed: 1,
            spill_dir: None,
            priority_refine: true,
            max_connections: 0,
            queue_depth: 0,
            spill_max_bytes: 0,
            trace_path: None,
            env: EnvConfig::default(),
            ..ServeOptions::default()
        });
        // Pre-warm so the sweep measures pure hit-path throughput.
        for w in &hot_mix {
            let resp = b.handle(&format!(r#"{{"op":"map","workload":"{}"}}"#, w.name()));
            anyhow::ensure!(parse(&resp)?.get("error").is_none(), "warm failed: {resp}");
        }
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        let wall_s = std::thread::scope(|scope| -> anyhow::Result<f64> {
            let server = scope.spawn(|| b.serve_tcp(listener));
            let t0 = Instant::now();
            let handles: Vec<_> = (0..clients)
                .map(|_| {
                    scope.spawn(move || -> anyhow::Result<()> {
                        let stream = TcpStream::connect(addr)?;
                        let mut writer = stream.try_clone()?;
                        let mut reader = BufReader::new(stream);
                        let mut line = String::new();
                        for i in 0..PER_CLIENT {
                            let w = hot_mix[i % hot_mix.len()];
                            writeln!(
                                writer,
                                r#"{{"op":"map","workload":"{}","return_map":true}}"#,
                                w.name()
                            )?;
                            line.clear();
                            reader.read_line(&mut line)?;
                            anyhow::ensure!(
                                parse(&line)?.get("error").is_none(),
                                "sweep request failed: {line}"
                            );
                        }
                        Ok(())
                    })
                })
                .collect();
            for h in handles {
                h.join().expect("client thread panicked")?;
            }
            let wall = t0.elapsed().as_secs_f64();
            // Shut the server down over a control connection.
            let stream = TcpStream::connect(addr)?;
            let mut writer = stream.try_clone()?;
            let mut reader = BufReader::new(stream);
            writeln!(writer, r#"{{"op":"shutdown"}}"#)?;
            let mut line = String::new();
            reader.read_line(&mut line)?;
            server.join().expect("server thread panicked")?;
            Ok(wall)
        })?;
        let total = (clients * PER_CLIENT) as f64;
        let rps = total / wall_s;
        println!("  {clients:>2} client(s): {total:>5.0} requests in {wall_s:.3} s  ({rps:>8.0} req/s)");
        sweep_rps.push(rps);
        sweep_rows.push(Json::obj(vec![
            ("clients", Json::Num(clients as f64)),
            ("requests", Json::Num(total)),
            ("wall_s", Json::Num(wall_s)),
            ("throughput_rps", Json::Num(rps)),
        ]));
    }
    let best_concurrent = sweep_rps[1..].iter().cloned().fold(f64::NAN, f64::max);
    let multi_client_scaling = best_concurrent > sweep_rps[0];
    println!(
        "  scaling: 1-client {:.0} req/s -> best concurrent {:.0} req/s (increasing: {multi_client_scaling})",
        sweep_rps[0], best_concurrent
    );

    // ---- spill tier round trip (ISSUE 5 tentpole acceptance) -----------
    // Cold-map, force-evict (demotes to disk), re-request: the entry must
    // come back from the spill tier with its refinement investment
    // intact, without re-running the cold search path.
    println!("\n== spill tier round trip ==");
    let spill_path = std::env::temp_dir().join(format!("egrl-serve-bench-spill-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&spill_path);
    let sb = Broker::new(ServeOptions {
        cache_cap: 16,
        deadline_ms: 10,
        refine_budget: 36_000,
        workers: 0,
        seed: 1,
        spill_dir: Some(spill_path.clone()),
        priority_refine: true,
        max_connections: 0,
        queue_depth: 0,
        spill_max_bytes: 0,
        trace_path: None,
        env: EnvConfig::default(),
        ..ServeOptions::default()
    });
    let t0 = Instant::now();
    let cold = parse(&sb.handle(r#"{"op":"map","workload":"resnet50"}"#))?;
    let cold_ms = t0.elapsed().as_secs_f64() * 1e3;
    anyhow::ensure!(
        cold.get("cache").and_then(Json::as_str) == Some("miss"),
        "spill phase expected a cold miss: {cold:?}"
    );
    let cold_iters = cold.get("refine_iters").and_then(Json::as_f64).unwrap_or(0.0);
    let ev = parse(&sb.handle(r#"{"op":"evict","workload":"resnet50"}"#))?;
    anyhow::ensure!(
        ev.get("spilled").and_then(Json::as_bool) == Some(true),
        "eviction did not spill: {ev:?}"
    );
    let t0 = Instant::now();
    let restored = parse(&sb.handle(r#"{"op":"map","workload":"resnet50"}"#))?;
    let restore_ms = t0.elapsed().as_secs_f64() * 1e3;
    let served_from_spill = restored.get("cache").and_then(Json::as_str) == Some("spill");
    let restored_iters = restored.get("refine_iters").and_then(Json::as_f64).unwrap_or(-1.0);
    let spill_stats = parse(&sb.handle(r#"{"op":"stats"}"#))?;
    let spill_hits = spill_stats.get("spill_hits").and_then(Json::as_f64).unwrap_or(0.0);
    println!(
        "  cold {cold_ms:.1} ms ({cold_iters:.0} iters) -> evict -> restore {restore_ms:.1} ms \
         (from spill: {served_from_spill}, iters preserved: {})",
        restored_iters == cold_iters
    );
    let _ = std::fs::remove_dir_all(&spill_path);

    // ---- multi-broker topology sweep (ISSUE 10 tentpole) ---------------
    // Aggregate fleet throughput vs. broker count: N proxying brokers
    // share one spill directory and shard the fingerprint space; a fixed
    // client pool spreads persistent connections round-robin across the
    // members. Every broker is pre-warmed through the forwarding loop
    // guard, so the replay measures the steady state: owned requests hit
    // locally, non-owned ones cost one proxy hop. On a single machine
    // the brokers compete for the same cores and the hop adds work, so
    // the acceptance bound is loose — the fleet must retain at least
    // half the single-broker rate (real scale-out needs real machines);
    // the full curve is uploaded for trending.
    println!("\n== multi-broker topology sweep ==");
    const FLEET_CLIENTS: usize = 6;
    const PER_FLEET_CLIENT: usize = 100;
    let fleet_spill =
        std::env::temp_dir().join(format!("egrl-serve-bench-fleet-{}", std::process::id()));
    let mut fleet_rows: Vec<Json> = Vec::new();
    let mut fleet_rps: Vec<f64> = Vec::new();
    for n in 1usize..=3 {
        let _ = std::fs::remove_dir_all(&fleet_spill);
        let listeners: Vec<TcpListener> = (0..n)
            .map(|_| TcpListener::bind("127.0.0.1:0"))
            .collect::<std::io::Result<_>>()?;
        let addrs: Vec<String> =
            listeners.iter().map(|l| Ok(l.local_addr()?.to_string())).collect::<anyhow::Result<_>>()?;
        let brokers: Vec<Broker> = addrs
            .iter()
            .map(|a| {
                Broker::open(ServeOptions {
                    cache_cap: 16,
                    deadline_ms: 0,
                    refine_budget: 36_000,
                    workers: 0,
                    seed: 1,
                    spill_dir: Some(fleet_spill.clone()),
                    peers: addrs.clone(),
                    self_addr: a.clone(),
                    proxy: true,
                    ..ServeOptions::default()
                })
            })
            .collect::<anyhow::Result<_>>()?;
        for b in &brokers {
            for w in &hot_mix {
                let resp = b.handle(&format!(
                    r#"{{"op":"map","workload":"{}","forwarded":true}}"#,
                    w.name()
                ));
                anyhow::ensure!(parse(&resp)?.get("error").is_none(), "fleet warm: {resp}");
            }
        }
        let wall_s = std::thread::scope(|scope| -> anyhow::Result<f64> {
            let servers: Vec<_> = brokers
                .iter()
                .zip(listeners)
                .map(|(b, l)| scope.spawn(move || b.serve_tcp(l)))
                .collect();
            let addrs = &addrs;
            let t0 = Instant::now();
            let clients: Vec<_> = (0..FLEET_CLIENTS)
                .map(|ci| {
                    scope.spawn(move || -> anyhow::Result<()> {
                        let stream = TcpStream::connect(addrs[ci % addrs.len()].as_str())?;
                        let mut writer = stream.try_clone()?;
                        let mut reader = BufReader::new(stream);
                        let mut line = String::new();
                        for i in 0..PER_FLEET_CLIENT {
                            let w = hot_mix[(ci + i) % hot_mix.len()];
                            writeln!(
                                writer,
                                r#"{{"op":"map","workload":"{}","return_map":true}}"#,
                                w.name()
                            )?;
                            line.clear();
                            reader.read_line(&mut line)?;
                            anyhow::ensure!(
                                parse(&line)?.get("error").is_none(),
                                "fleet request failed: {line}"
                            );
                        }
                        Ok(())
                    })
                })
                .collect();
            for c in clients {
                c.join().expect("fleet client panicked")?;
            }
            let wall = t0.elapsed().as_secs_f64();
            for (addr, server) in addrs.iter().zip(servers) {
                let stream = TcpStream::connect(addr.as_str())?;
                let mut writer = stream.try_clone()?;
                let mut reader = BufReader::new(stream);
                writeln!(writer, r#"{{"op":"shutdown"}}"#)?;
                let mut line = String::new();
                reader.read_line(&mut line)?;
                server.join().expect("fleet server panicked")?;
            }
            Ok(wall)
        })?;
        let total = (FLEET_CLIENTS * PER_FLEET_CLIENT) as f64;
        let rps = total / wall_s;
        let forwarded: f64 = brokers
            .iter()
            .map(|b| {
                parse(&b.handle(r#"{"op":"stats"}"#))
                    .ok()
                    .and_then(|s| s.get("forwarded").and_then(Json::as_f64))
                    .unwrap_or(0.0)
            })
            .sum();
        println!(
            "  {n} broker(s): {total:>4.0} requests in {wall_s:.3} s  ({rps:>8.0} req/s, {forwarded:.0} forwarded)"
        );
        fleet_rps.push(rps);
        fleet_rows.push(Json::obj(vec![
            ("brokers", Json::Num(n as f64)),
            ("clients", Json::Num(FLEET_CLIENTS as f64)),
            ("requests", Json::Num(total)),
            ("wall_s", Json::Num(wall_s)),
            ("throughput_rps", Json::Num(rps)),
            ("forwarded", Json::Num(forwarded)),
        ]));
    }
    let _ = std::fs::remove_dir_all(&fleet_spill);
    let best_fleet = fleet_rps[1..].iter().cloned().fold(f64::NAN, f64::max);
    let multi_broker_scaling = best_fleet >= fleet_rps[0] * 0.5;
    println!(
        "  fleet: 1-broker {:.0} req/s -> best multi-broker {:.0} req/s (>= half single-broker rate: {multi_broker_scaling})",
        fleet_rps[0], best_fleet
    );

    let json = Json::obj(vec![
        ("schema", Json::str("egrl-bench-serve-v1")),
        (
            "workload_mix",
            Json::arr(mix.iter().map(|w| Json::str(w.name()))),
        ),
        ("zipf_exponent", Json::Num(1.0)),
        ("requests", Json::Num(REQUESTS as f64)),
        ("wall_s", Json::Num(wall_s)),
        ("throughput_rps", Json::Num(throughput_rps)),
        ("hit", hit_json),
        ("cold", cold_json),
        ("hit_rate", Json::Num(hit_rate)),
        ("cold_over_hit_p99", Json::Num(cold_over_hit_p99)),
        ("target_cold_over_hit_p99", Json::Num(100.0)),
        ("latency_target_met", Json::Bool(latency_target_met)),
        (
            "anytime_curve",
            Json::arr(curve.iter().map(|&(iters, lat)| {
                Json::obj(vec![
                    ("refine_iters", Json::Num(iters as f64)),
                    ("true_latency_s", Json::Num(lat)),
                ])
            })),
        ),
        ("curve_monotone", Json::Bool(curve_monotone)),
        ("final_speedup", Json::Num(final_entry.speedup)),
        ("multi_client", Json::Arr(sweep_rows)),
        ("multi_client_scaling", Json::Bool(multi_client_scaling)),
        ("multi_broker", Json::Arr(fleet_rows)),
        ("multi_broker_scaling", Json::Bool(multi_broker_scaling)),
        (
            "spill",
            Json::obj(vec![
                ("cold_ms", Json::Num(cold_ms)),
                ("restore_ms", Json::Num(restore_ms)),
                ("served_from_spill", Json::Bool(served_from_spill)),
                ("refine_iters_preserved", Json::Bool(restored_iters == cold_iters)),
                ("spill_hits", Json::Num(spill_hits)),
            ]),
        ),
        ("broker_stats", stats),
    ]);
    std::fs::write("BENCH_serve.json", json.to_string_pretty())?;
    println!("\nwrote BENCH_serve.json");
    println!(
        "targets (ISSUE 4): hit p99 {}x faster than cold (>= 100x: {}), anytime curve monotone: {}",
        cold_over_hit_p99 as i64, latency_target_met, curve_monotone
    );
    println!(
        "targets (ISSUE 5): throughput increases with clients: {multi_client_scaling}, \
         spill restore without cold search: {served_from_spill}"
    );
    println!(
        "targets (ISSUE 10): fleet retains >= half the single-broker rate on one machine: \
         {multi_broker_scaling}"
    );
    Ok(())
}
