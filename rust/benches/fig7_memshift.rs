//! Figure 7 reproduction: memory-map shifts between the native compiler
//! and the learned agent.
//!
//! Top panel: 3×3 transition matrices (how the agent re-distributed the
//! bytes the compiler placed in each memory). Bottom panel: per-tensor
//! mapping strips for ResNet-50 and ResNet-101. Plus the §5.2.1
//! statistics the paper derives from this figure: DRAM avoidance
//! (especially for weights) and activation contiguity.

use std::sync::Arc;

use egrl::bench_harness::Table;
use egrl::config::EgrlConfig;
use egrl::coordinator::{Mode, Trainer};
use egrl::env::MappingEnv;
use egrl::metrics::RunLog;
use egrl::runtime::Runtime;
use egrl::viz::{analysis, transition};
use egrl::workloads::Workload;

fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let steps = env_u64("EGRL_BENCH_STEPS", 1500);
    // Mixed GNN+Boltzmann population when artifacts exist (paper's EA).
    let runtime = {
        let dir = Runtime::default_dir();
        if dir.join("manifest.json").exists() { Some(Runtime::open(dir)?) } else { None }
    };
    let mut stats = Table::new(&[
        "workload",
        "W-DRAM% compiler",
        "W-DRAM% agent",
        "A-DRAM% compiler",
        "A-DRAM% agent",
        "contig compiler",
        "contig agent",
        "speedup",
    ]);

    for w in Workload::all() {
        let env = Arc::new(MappingEnv::nnpi(w.build(), 31));
        let cfg = EgrlConfig { seed: 31, total_steps: steps, ..Default::default() };
        let mut trainer = Trainer::new(env.clone(), cfg, Mode::EaOnly, runtime.as_ref())?;
        let mut log = RunLog::new(w.name(), "ea", 31);
        let res = trainer.run(&mut log)?;

        println!("\n--- {} : transition matrix (compiler → agent) ---", w.name());
        println!(
            "{}",
            transition::render_matrix(&transition::transition_matrix(
                &env.graph,
                &env.compiler_map,
                &res.best_map
            ))
        );
        // Fig 7 bottom shows strips for the ResNets.
        if w != Workload::Bert {
            println!("per-tensor strips (D=DRAM, L=LLC, S=SRAM, .=no weight):");
            print!("{}", transition::render_strips(&env.graph, &env.compiler_map, "compiler"));
            print!("{}", transition::render_strips(&env.graph, &res.best_map, "agent"));
        }

        let cb = analysis::analyze(&env.graph, &env.compiler_map);
        let ab = analysis::analyze(&env.graph, &res.best_map);
        stats.row(&[
            w.name().into(),
            format!("{:.1}", cb.weights.dram_fraction() * 100.0),
            format!("{:.1}", ab.weights.dram_fraction() * 100.0),
            format!("{:.1}", cb.activations.dram_fraction() * 100.0),
            format!("{:.1}", ab.activations.dram_fraction() * 100.0),
            format!("{:.2}", cb.contiguity),
            format!("{:.2}", ab.contiguity),
            format!("{:.3}", res.best_speedup),
        ]);
    }

    println!("\n=== Figure 7 / §5.2.1: placement-strategy statistics ===\n");
    stats.print();
    println!(
        "\npaper claims to check: the agent's maps avoid DRAM (W-DRAM% agent \
         < compiler, most prominently for weights) and favour contiguity \
         (contig agent ≥ compiler)."
    );
    Ok(())
}
