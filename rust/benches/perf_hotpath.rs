//! Performance benchmark of every hot path (EXPERIMENTS.md §Perf).
//!
//! L3 (native Rust): environment step (rectify + liveness-aware capacity
//! accounting + latency model), its components, Boltzmann decode/sample,
//! EA generation machinery, Jaccard/MDS analysis.
//!
//! Runtime path (with artifacts): policy_fwd execution per size variant
//! and one sac_update step — the PJRT-side costs that bound EGRL's
//! wall-clock on this host.

use egrl::bench_harness::Bench;
use egrl::ea::BoltzmannChromosome;
use egrl::env::MappingEnv;
use egrl::gnn::PolicyRunner;
use egrl::mapping::MemoryMap;
use egrl::rl::{SacLearner, Transition};
use egrl::runtime::Runtime;
use egrl::sim::compiler::CompilerWorkspace;
use egrl::sim::liveness::Liveness;
use egrl::utils::Rng;
use egrl::viz::embed;
use egrl::workloads::Workload;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(7);

    // ---- L3: environment step throughput per workload ---------------------
    let mut b = Bench::new("L3 simulator hot path");
    for w in Workload::all() {
        let env = MappingEnv::nnpi(w.build(), 1);
        let n = env.num_nodes();
        let mut ws = CompilerWorkspace::default();
        // A mixed map that exercises spilling.
        let actions: Vec<[usize; 2]> = (0..n).map(|i| [i % 3, (i + 1) % 3]).collect();
        let map = MemoryMap::from_actions(&actions);
        let mut local_rng = rng.fork();
        // BEFORE (perf pass): fresh workspace each step — the naive
        // allocating path a first implementation uses.
        b.measure_throughput(
            &format!("env.step alloc ({} nodes, {})", n, w.name()),
            1.0,
            200,
            0.5,
            || {
                std::hint::black_box(env.step(&map, &mut local_rng));
            },
        );
        // AFTER: workspace-reusing hot path (CompilerWorkspace).
        b.measure_throughput(
            &format!("env.step reuse ({} nodes, {})", n, w.name()),
            1.0,
            200,
            0.5,
            || {
                std::hint::black_box(env.step_with(&map, &mut local_rng, &mut ws));
            },
        );
    }

    // ---- L3 components ------------------------------------------------------
    let env = MappingEnv::nnpi(Workload::Bert.build(), 2);
    let n = env.num_nodes();
    let map = env.compiler_map.clone();
    let mut ws = CompilerWorkspace::default();
    b.measure("rectify only (bert)", 200, 0.5, || {
        std::hint::black_box(env.compiler.rectify_with(&env.graph, &env.liveness, &map, &mut ws));
    });
    b.measure("latency model only (bert)", 200, 0.5, || {
        std::hint::black_box(env.latency.latency(&env.graph, &map));
    });
    b.measure("liveness analysis (bert)", 200, 0.5, || {
        std::hint::black_box(Liveness::analyze(&env.graph));
    });
    b.measure("feature extraction (bert)", 200, 0.5, || {
        std::hint::black_box(env.graph.feature_matrix());
    });

    // ---- EA machinery -------------------------------------------------------
    let chrom = BoltzmannChromosome::random(n, 1.0, &mut rng);
    let mut local_rng = rng.fork();
    b.measure_throughput("boltzmann decode+sample (bert nodes)", n as f64, 200, 0.5, || {
        std::hint::black_box(chrom.sample_map(&mut local_rng));
    });
    let maps: Vec<MemoryMap> = (0..24)
        .map(|_| {
            let actions: Vec<[usize; 2]> =
                (0..57).map(|_| [local_rng.below(3), local_rng.below(3)]).collect();
            MemoryMap::from_actions(&actions)
        })
        .collect();
    b.measure("jaccard distance matrix (24 maps)", 50, 0.3, || {
        std::hint::black_box(embed::distance_matrix(&maps));
    });
    let d = embed::distance_matrix(&maps);
    b.measure("MDS 2-D embedding (24 maps)", 20, 0.3, || {
        std::hint::black_box(embed::mds_2d(&d, maps.len()));
    });

    // ---- runtime path (artifacts) ---------------------------------------------
    let dir = Runtime::default_dir();
    if dir.join("manifest.json").exists() {
        let rt = Runtime::open(dir)?;
        let mut rb = Bench::new("PJRT runtime path");
        for w in Workload::all() {
            let env = MappingEnv::nnpi(w.build(), 3);
            let runner = PolicyRunner::for_env(&rt, &env)?;
            let params = rt.actor_init()?;
            rb.measure(
                &format!("policy_fwd execute (N={})", runner.n_artifact),
                10,
                1.0,
                || {
                    std::hint::black_box(runner.probs(&params).unwrap());
                },
            );
        }
        // One SAC step on the smallest variant (the big ones differ only
        // in the N² term; compiling all three costs minutes).
        let env = MappingEnv::nnpi(Workload::ResNet50.build(), 4);
        let mut sac = SacLearner::new(&rt, &env)?;
        let tr = Transition { actions: vec![[0, 0]; env.num_nodes()], reward: 1.0 };
        let batch: Vec<&Transition> = (0..sac.batch_size()).map(|_| &tr).collect();
        let mut local_rng = rng.fork();
        rb.measure("sac_update execute (N=64, B=24)", 3, 2.0, || {
            std::hint::black_box(sac.update(&batch, &mut local_rng).unwrap());
        });
    } else {
        println!("\n(PJRT runtime benches skipped: artifacts missing)");
    }

    println!("\nperf targets (DESIGN.md §8): env.step ≥ 50k/s on ResNet-50-sized graphs;");
    println!("the simulator must never be the bottleneck relative to artifact execution.");
    Ok(())
}
