//! Performance benchmark of every hot path (EXPERIMENTS.md §Perf).
//!
//! L3 (native Rust): environment step (rectify + liveness-aware capacity
//! accounting + latency model) on all three of its paths (allocating /
//! workspace-reusing / zero-allocation in-place), the table-driven vs.
//! naive latency evaluators, Boltzmann decode/sample, EA generation
//! machinery (including the seed's serial allocating rollout loop vs. the
//! parallel rollout engine), Jaccard/MDS analysis.
//!
//! Runtime path (with artifacts): policy_fwd execution per size variant
//! and one sac_update step — the PJRT-side costs that bound EGRL's
//! wall-clock on this host.
//!
//! Besides the stdout report, writes `BENCH_hotpath.json` (all raw
//! measurements + derived speedup ratios) so future PRs can track the
//! perf trajectory mechanically.

use std::sync::Arc;

use egrl::bench_harness::Bench;
use egrl::config::EgrlConfig;
use egrl::coordinator::{Mode, Trainer};
use egrl::ea::population::{EvolveParams, Genome, Population};
use egrl::ea::BoltzmannChromosome;
use egrl::env::{EnvConfig, MappingEnv};
use egrl::gnn::PolicyRunner;
use egrl::mapping::{MemKind, MemoryMap, NodePlacement};
use egrl::rl::{Replay, SacLearner, Transition};
use egrl::runtime::Runtime;
use egrl::serve::{Broker, ServeOptions};
use egrl::sim::compiler::CompilerWorkspace;
use egrl::sim::liveness::Liveness;
use egrl::utils::json::Json;
use egrl::utils::Rng;
use egrl::viz::embed;
use egrl::workloads::Workload;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(7);

    // ---- L3: environment step throughput per workload ---------------------
    let mut b = Bench::new("L3 simulator hot path");
    for w in Workload::all() {
        let env = MappingEnv::nnpi(w.build(), 1);
        let n = env.num_nodes();
        let mut ws = CompilerWorkspace::default();
        // A mixed map that exercises spilling.
        let actions: Vec<[usize; 2]> = (0..n).map(|i| [i % 3, (i + 1) % 3]).collect();
        let map = MemoryMap::from_actions(&actions);
        let mut local_rng = rng.fork();
        // BEFORE (perf pass): fresh workspace each step — the naive
        // allocating path a first implementation uses.
        b.measure_throughput(
            &format!("env.step alloc ({} nodes, {})", n, w.name()),
            1.0,
            200,
            0.5,
            || {
                std::hint::black_box(env.step(&map, &mut local_rng));
            },
        );
        // Workspace reuse, but still one owned outcome clone per step.
        b.measure_throughput(
            &format!("env.step reuse ({} nodes, {})", n, w.name()),
            1.0,
            200,
            0.5,
            || {
                std::hint::black_box(env.step_with(&map, &mut local_rng, &mut ws));
            },
        );
        // AFTER: the zero-allocation in-place path the rollout engine uses.
        let mut buf = map.clone();
        b.measure_throughput(
            &format!("env.step in-place ({} nodes, {})", n, w.name()),
            1.0,
            200,
            0.5,
            || {
                buf.placements.copy_from_slice(&map.placements);
                std::hint::black_box(env.step_in_place(&mut buf, &mut local_rng, &mut ws));
            },
        );
    }

    // ---- L3 components ------------------------------------------------------
    let env = MappingEnv::nnpi(Workload::Bert.build(), 2);
    let map = env.compiler_map.clone();
    let mut ws = CompilerWorkspace::default();
    b.measure("rectify only (bert)", 200, 0.5, || {
        std::hint::black_box(env.compiler.rectify_with(&env.graph, &env.liveness, &map, &mut ws));
    });
    let mut buf = map.clone();
    b.measure("rectify in-place (bert)", 200, 0.5, || {
        buf.placements.copy_from_slice(&map.placements);
        std::hint::black_box(env.compiler.rectify_in_place(
            &env.graph,
            &env.liveness,
            &mut buf,
            &mut ws,
        ));
    });
    b.measure("latency naive (bert)", 200, 0.5, || {
        std::hint::black_box(env.latency.latency(&env.graph, &map));
    });
    b.measure("latency table (bert)", 200, 0.5, || {
        std::hint::black_box(env.cost_table.latency(&map));
    });
    // Mutation-local re-evaluation: score a single-node activation move
    // via latency_delta (O(preds + succs·preds)) instead of re-walking
    // the whole graph.
    {
        let node = env.num_nodes() / 2;
        let old = map.placements[node];
        let mut moved = map.clone();
        moved.placements[node].activation = MemKind::from_index((old.activation.index() + 1) % 3);
        b.measure("latency delta single move (bert)", 200, 0.5, || {
            std::hint::black_box(env.cost_table.latency_delta(&moved, node, old));
        });
    }
    b.measure("liveness analysis (bert)", 200, 0.5, || {
        std::hint::black_box(Liveness::analyze(&env.graph));
    });
    b.measure("feature extraction (bert)", 200, 0.5, || {
        std::hint::black_box(env.graph.feature_matrix());
    });
    let n = env.num_nodes();

    // ---- EA machinery -------------------------------------------------------
    let chrom = BoltzmannChromosome::random(n, 1.0, &mut rng);
    let mut local_rng = rng.fork();
    b.measure_throughput("boltzmann decode+sample (bert nodes)", n as f64, 200, 0.5, || {
        std::hint::black_box(chrom.sample_map(&mut local_rng));
    });
    let maps: Vec<MemoryMap> = (0..24)
        .map(|_| {
            let actions: Vec<[usize; 2]> =
                (0..57).map(|_| [local_rng.below(3), local_rng.below(3)]).collect();
            MemoryMap::from_actions(&actions)
        })
        .collect();
    b.measure("jaccard distance matrix (24 maps)", 50, 0.3, || {
        std::hint::black_box(embed::distance_matrix(&maps));
    });
    let d = embed::distance_matrix(&maps);
    b.measure("MDS 2-D embedding (24 maps)", 20, 0.3, || {
        std::hint::black_box(embed::mds_2d(&d, maps.len()));
    });

    // ---- Local search: incremental move evaluation vs the full step ---------
    // The same stream of single-node candidate moves off the compiler map
    // priced two ways: BEFORE — a full env step per candidate (rectify the
    // whole proposal + walk the whole graph), what every agent paid until
    // the move-evaluation engine existed; AFTER — MappingEnv::try_move
    // (O(degree)-ish capacity check + cached-term latency re-sum).
    let ls_speedup;
    let ls_moves_per_s;
    let ls_full_moves_per_s;
    {
        let env = MappingEnv::nnpi(Workload::ResNet50.build(), 5);
        let n = env.num_nodes();
        let base = env.compiler_map.clone();
        let moves: Vec<(usize, NodePlacement)> = (0..n * 9)
            .map(|i| {
                let node = i % n;
                (
                    node,
                    NodePlacement {
                        weight: MemKind::from_index((i / n) % 3),
                        activation: MemKind::from_index((i / (n * 3)) % 3),
                    },
                )
            })
            .collect();
        let mut ws = CompilerWorkspace::default();
        let mut buf = base.clone();
        let mut rng_full = rng.fork();
        let mut i_full = 0usize;
        b.measure_throughput("move eval full step (resnet50)", 1.0, 400, 0.5, || {
            let (node, p) = moves[i_full % moves.len()];
            i_full += 1;
            buf.placements.copy_from_slice(&base.placements);
            buf.placements[node] = p;
            std::hint::black_box(env.step_in_place(&mut buf, &mut rng_full, &mut ws));
        });
        let mut st = env.search_state(&base);
        let mut rng_inc = rng.fork();
        let mut i_inc = 0usize;
        b.measure_throughput("move eval try_move (resnet50)", 1.0, 400, 0.5, || {
            let (node, p) = moves[i_inc % moves.len()];
            i_inc += 1;
            std::hint::black_box(env.try_move(&mut st, node, p, &mut rng_inc));
        });
        let full_s = b.mean_s("move eval full step (resnet50)").unwrap_or(f64::NAN);
        let inc_s = b.mean_s("move eval try_move (resnet50)").unwrap_or(f64::NAN);
        ls_speedup = full_s / inc_s;
        ls_moves_per_s = 1.0 / inc_s;
        ls_full_moves_per_s = 1.0 / full_s;
        println!(
            "\nlocal-search move eval: {:.0}/s incremental vs {:.0}/s full-step ({:.1}x)",
            ls_moves_per_s, ls_full_moves_per_s, ls_speedup
        );
        let ls_json = Json::obj(vec![
            ("schema", Json::str("egrl-bench-localsearch-v1")),
            ("workload", Json::str("resnet50")),
            ("moves_per_sec_try_move", Json::Num(ls_moves_per_s)),
            ("moves_per_sec_full_step", Json::Num(ls_full_moves_per_s)),
            ("try_move_speedup_vs_full_step", Json::Num(ls_speedup)),
            ("target_speedup", Json::Num(10.0)),
            ("meets_target", Json::Bool(ls_speedup >= 10.0)),
        ]);
        std::fs::write("BENCH_localsearch.json", ls_json.to_string_pretty())?;
        println!("wrote BENCH_localsearch.json");
    }

    // ---- Trainer::generation: seed serial path vs the rollout engine -------
    // BEFORE: a faithful emulation of the seed trainer's generation — serial
    // rollouts through the allocating env.step (fresh workspace + owned
    // outcome per step), then evolution. AFTER: the real Trainer::generation
    // on the parallel, zero-allocation engine at various thread counts.
    {
        let gen_env = MappingEnv::nnpi(Workload::ResNet50.build(), 3);
        let pop_size = 20;
        let gn = gen_env.num_nodes();
        let mut pop = Population::init(pop_size, pop_size, gn, 1.0, None, &mut rng);
        let mut replay = Replay::new(100_000);
        let params = EvolveParams {
            elites: 4,
            mut_prob: 0.9,
            mut_std: 0.1,
            mut_frac: 0.1,
            tournament: 3,
        };
        let mut seed_rng = rng.fork();
        b.measure("generation BEFORE (seed serial, alloc)", 30, 0.5, || {
            for i in 0..pop.len() {
                let map = match &pop.members[i].genome {
                    Genome::Boltzmann(bz) => bz.sample_map(&mut seed_rng),
                    Genome::Gnn(_) => unreachable!("artifact-free population"),
                };
                let out = gen_env.step(&map, &mut seed_rng);
                replay.push(Transition::from_map(&map, out.reward));
                pop.members[i].fitness = out.reward;
                std::hint::black_box(&out.rectified);
            }
            let mut ev_rng = seed_rng.fork();
            pop.evolve(params, &mut ev_rng, &mut |_g: &[f32]| -> Option<Vec<f32>> { None });
        });

        for threads in [1usize, 2, 4] {
            let cfg = EgrlConfig {
                threads,
                seed: 3,
                pop_size,
                elites: 4,
                total_steps: u64::MAX,
                ..Default::default()
            };
            let env = Arc::new(MappingEnv::nnpi(Workload::ResNet50.build(), 3));
            let mut trainer = Trainer::new(env, cfg, Mode::EaOnly, None)?;
            b.measure(&format!("generation AFTER (engine, threads={threads})"), 30, 0.5, || {
                std::hint::black_box(trainer.generation().unwrap());
            });
        }
    }

    // ---- derived ratios -----------------------------------------------------
    let ratio = |num: &str, den: &str| -> f64 {
        match (b.mean_s(num), b.mean_s(den)) {
            (Some(a), Some(c)) if c > 0.0 => a / c,
            _ => f64::NAN,
        }
    };
    let gen_speedup_t4 =
        ratio("generation BEFORE (seed serial, alloc)", "generation AFTER (engine, threads=4)");
    let gen_speedup_t1 =
        ratio("generation BEFORE (seed serial, alloc)", "generation AFTER (engine, threads=1)");
    let latency_speedup = ratio("latency naive (bert)", "latency table (bert)");
    let delta_speedup = ratio("latency table (bert)", "latency delta single move (bert)");
    println!("\nderived:");
    println!("  generation speedup (threads=4 vs seed serial): {gen_speedup_t4:.2}x");
    println!("  generation speedup (threads=1 vs seed serial): {gen_speedup_t1:.2}x");
    println!("  latency table vs naive:                        {latency_speedup:.2}x");
    println!("  latency_delta vs full table recompute:         {delta_speedup:.2}x");

    // ---- telemetry overhead: instrumented vs dark serving (ISSUE 9) --------
    // Two identical brokers replay the same deterministic polish stream;
    // one appends timed spans to a JSON-lines file sink per request, the
    // other runs dark (the `Trace` handle is an inlined no-op). Rounds
    // are interleaved A/B so slow machine drift (thermal, noisy
    // neighbours) hits both arms equally and cancels in the ratio.
    {
        let mk = |trace_path: Option<std::path::PathBuf>| {
            Broker::new(ServeOptions {
                cache_cap: 16,
                deadline_ms: 0,
                refine_budget: 36_000,
                workers: 0,
                seed: 1,
                spill_dir: None,
                priority_refine: true,
                max_connections: 0,
                queue_depth: 0,
                spill_max_bytes: 0,
                trace_path,
                env: EnvConfig::default(),
                ..ServeOptions::default()
            })
        };
        let trace_file =
            std::env::temp_dir().join(format!("egrl-obs-bench-{}.jsonl", std::process::id()));
        let dark = mk(None);
        let instr = mk(Some(trace_file.clone()));
        // Seed the cache outside the timed region: every timed round is
        // then one polish op (a full no-improvement refinement sweep at
        // steady state — identical work in both arms, since the polish
        // RNG seed depends only on the broker seed and the op ordinal).
        for b in [&dark, &instr] {
            std::hint::black_box(b.handle(r#"{"op":"map","workload":"bert"}"#));
        }
        let round = |b: &Broker| {
            std::hint::black_box(b.handle(r#"{"op":"polish","workload":"bert","budget":9000}"#));
        };
        const WARMUP: usize = 5;
        const ROUNDS: usize = 60;
        for _ in 0..WARMUP {
            round(&dark);
            round(&instr);
        }
        let mut dark_s = 0.0;
        let mut instr_s = 0.0;
        for _ in 0..ROUNDS {
            let t0 = std::time::Instant::now();
            round(&dark);
            dark_s += t0.elapsed().as_secs_f64();
            let t0 = std::time::Instant::now();
            round(&instr);
            instr_s += t0.elapsed().as_secs_f64();
        }
        let _ = std::fs::remove_file(&trace_file);
        let obs_ratio = instr_s / dark_s;
        let obs_target = 1.05;
        println!(
            "\ntelemetry overhead: dark {:.1} µs/req vs instrumented {:.1} µs/req \
             (ratio {obs_ratio:.3}, target <= {obs_target})",
            dark_s / ROUNDS as f64 * 1e6,
            instr_s / ROUNDS as f64 * 1e6
        );
        let obs_json = Json::obj(vec![
            ("schema", Json::str("egrl-bench-obs-v1")),
            ("workload", Json::str("bert")),
            ("rounds", Json::Num(ROUNDS as f64)),
            ("dark_s", Json::Num(dark_s)),
            ("instrumented_s", Json::Num(instr_s)),
            ("telemetry_overhead_ratio", Json::Num(obs_ratio)),
            ("max_ratio", Json::Num(obs_target)),
            ("meets_target", Json::Bool(obs_ratio <= obs_target)),
        ]);
        std::fs::write("BENCH_obs.json", obs_json.to_string_pretty())?;
        println!("wrote BENCH_obs.json");
    }

    // ---- runtime path (artifacts) ---------------------------------------------
    let dir = Runtime::default_dir();
    if dir.join("manifest.json").exists() {
        let rt = Runtime::open(dir)?;
        let mut rb = Bench::new("PJRT runtime path");
        for w in Workload::all() {
            let env = MappingEnv::nnpi(w.build(), 3);
            let runner = PolicyRunner::for_env(&rt, &env)?;
            let params = rt.actor_init()?;
            rb.measure(
                &format!("policy_fwd execute (N={})", runner.n_artifact),
                10,
                1.0,
                || {
                    std::hint::black_box(runner.probs(&params).unwrap());
                },
            );
        }
        // One SAC step on the smallest variant (the big ones differ only
        // in the N² term; compiling all three costs minutes).
        let env = MappingEnv::nnpi(Workload::ResNet50.build(), 4);
        let mut sac = SacLearner::new(&rt, &env)?;
        let tr = Transition { actions: vec![[0, 0]; env.num_nodes()], reward: 1.0 };
        let batch: Vec<&Transition> = (0..sac.batch_size()).map(|_| &tr).collect();
        let mut local_rng = rng.fork();
        rb.measure("sac_update execute (N=64, B=24)", 3, 2.0, || {
            std::hint::black_box(sac.update(&batch, &mut local_rng).unwrap());
        });
    } else {
        println!("\n(PJRT runtime benches skipped: artifacts missing)");
    }

    // ---- machine-readable dump ----------------------------------------------
    let json = Json::obj(vec![
        ("schema", Json::str("egrl-bench-hotpath-v1")),
        ("measurements", b.to_json()),
        (
            "derived",
            Json::obj(vec![
                ("generation_speedup_threads4_vs_seed", Json::Num(gen_speedup_t4)),
                ("generation_speedup_threads1_vs_seed", Json::Num(gen_speedup_t1)),
                ("latency_table_speedup_vs_naive", Json::Num(latency_speedup)),
                ("latency_delta_speedup_vs_full_recompute", Json::Num(delta_speedup)),
                ("localsearch_try_move_speedup_vs_full_step", Json::Num(ls_speedup)),
                ("localsearch_moves_per_sec", Json::Num(ls_moves_per_s)),
                ("localsearch_full_step_moves_per_sec", Json::Num(ls_full_moves_per_s)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_hotpath.json", json.to_string_pretty())?;
    println!("\nwrote BENCH_hotpath.json");

    println!("\nperf targets (DESIGN.md §8): env.step ≥ 50k/s on ResNet-50-sized graphs;");
    println!("the simulator must never be the bottleneck relative to artifact execution.");
    Ok(())
}
