//! Figure 4 reproduction: final speedup vs. the native compiler for
//! EGRL / EA / PG / Greedy-DP on ResNet-50, ResNet-101 and BERT,
//! mean ± std over seeds, with the paper's reported numbers alongside.
//!
//! Default budgets are scaled down for the single-core bench image
//! (the paper's full 4000-iteration × 5-seed protocol is
//! `EGRL_BENCH_STEPS=4000 EGRL_BENCH_SEEDS=5 cargo bench --bench fig4_speedup`,
//! and `egrl train --agent ... --steps 4000` reproduces single runs).
//! EGRL/PG rows need `artifacts/`; without them the bench prints the
//! artifact-free subset (EA, Greedy-DP) and says so.
//!
//! Expected *shape* (DESIGN.md §4): EGRL ≥ EA > compiler(1.0) everywhere;
//! Greedy-DP beats the compiler only on ResNet-101 and collapses on BERT;
//! PG alone stays below 1.

use std::sync::Arc;

use egrl::agents::{GreedyDp, MappingAgent};
use egrl::bench_harness::{pm, Table};
use egrl::config::EgrlConfig;
use egrl::coordinator::{Mode, Trainer};
use egrl::env::MappingEnv;
use egrl::metrics::{RunLog, SeedAggregate};
use egrl::runtime::Runtime;
use egrl::utils::Rng;
use egrl::workloads::Workload;

/// Paper Figure-4 final speedups: (workload, agent) → value.
fn paper_value(w: Workload, agent: &str) -> f64 {
    match (w, agent) {
        (Workload::ResNet50, "egrl") => 1.28,
        (Workload::ResNet50, "ea") => 1.06,
        (Workload::ResNet50, "pg") => 0.29,
        (Workload::ResNet50, "greedy-dp") => 0.72,
        (Workload::ResNet101, "egrl") => 1.78,
        (Workload::ResNet101, "ea") => 1.47,
        (Workload::ResNet101, "pg") => 0.23,
        (Workload::ResNet101, "greedy-dp") => 1.27,
        (Workload::Bert, "egrl") => 1.66,
        (Workload::Bert, "ea") => 1.64,
        (Workload::Bert, "pg") => 0.21,
        (Workload::Bert, "greedy-dp") => 0.67,
        _ => f64::NAN,
    }
}

fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let steps = env_u64("EGRL_BENCH_STEPS", 700);
    let seeds = env_u64("EGRL_BENCH_SEEDS", 3);
    // PG-path budgets are smaller: each SAC update costs seconds of CPU.
    let pg_steps = env_u64("EGRL_BENCH_PG_STEPS", 250.min(steps));
    let pg_seeds = env_u64("EGRL_BENCH_PG_SEEDS", 1.min(seeds));

    let runtime = {
        let dir = Runtime::default_dir();
        if dir.join("manifest.json").exists() {
            Some(Runtime::open(dir)?)
        } else {
            eprintln!("fig4: artifacts missing — EGRL/PG rows skipped (run `make artifacts`)");
            None
        }
    };

    let mut table = Table::new(&[
        "workload", "agent", "measured speedup", "paper", "iters/seed", "seeds",
    ]);

    for w in Workload::all() {
        // --- EA: the paper's ablation = the EGRL population without PG,
        // i.e. the MIXED GNN+Boltzmann population (fraction 0.2). With
        // artifacts present we run exactly that; without them we fall
        // back to an all-Boltzmann population (much weaker — noted).
        let runs: Vec<RunLog> = (0..seeds)
            .map(|s| {
                let env = Arc::new(MappingEnv::nnpi(w.build(), s));
                let cfg = EgrlConfig { seed: s, total_steps: steps, ..Default::default() };
                let mut t = Trainer::new(env, cfg, Mode::EaOnly, runtime.as_ref()).unwrap();
                let mut log = RunLog::new(w.name(), "ea", s);
                t.run(&mut log).unwrap();
                log
            })
            .collect();
        let agg = SeedAggregate::from_runs(&runs);
        table.row(&[
            w.name().into(),
            "ea".into(),
            pm(agg.summary.mean, agg.summary.std),
            format!("{:.2}", paper_value(w, "ea")),
            steps.to_string(),
            seeds.to_string(),
        ]);

        // --- Greedy-DP ------------------------------------------------------
        let runs: Vec<RunLog> = (0..seeds)
            .map(|s| {
                let env = MappingEnv::nnpi(w.build(), s);
                let mut agent = GreedyDp::default();
                let mut rng = Rng::new(s);
                let mut log = RunLog::new(w.name(), "greedy-dp", s);
                agent.run(&env, steps, &mut rng, &mut log);
                log
            })
            .collect();
        let agg = SeedAggregate::from_runs(&runs);
        table.row(&[
            w.name().into(),
            "greedy-dp".into(),
            pm(agg.summary.mean, agg.summary.std),
            format!("{:.2}", paper_value(w, "greedy-dp")),
            steps.to_string(),
            seeds.to_string(),
        ]);

        // --- EGRL + PG (need artifacts) --------------------------------------
        if let (Some(rt), true) = (&runtime, pg_seeds > 0) {
            // Sparser SAC updates on the big artifact keep wall-clock sane.
            let update_every = if w == Workload::Bert { 84 } else { 21 };
            for (mode, name) in [(Mode::Egrl, "egrl"), (Mode::PgOnly, "pg")] {
                let runs: Vec<RunLog> = (0..pg_seeds)
                    .map(|s| {
                        let env = Arc::new(MappingEnv::nnpi(w.build(), s));
                        let cfg = EgrlConfig {
                            seed: s,
                            total_steps: pg_steps,
                            update_every,
                            pg_rollouts: if mode == Mode::PgOnly { 4 } else { 1 },
                            ..Default::default()
                        };
                        let mut t = Trainer::new(env, cfg, mode, Some(rt)).unwrap();
                        let mut log = RunLog::new(w.name(), name, s);
                        t.run(&mut log).unwrap();
                        log
                    })
                    .collect();
                let agg = SeedAggregate::from_runs(&runs);
                table.row(&[
                    w.name().into(),
                    name.into(),
                    pm(agg.summary.mean, agg.summary.std),
                    format!("{:.2}", paper_value(w, name)),
                    pg_steps.to_string(),
                    pg_seeds.to_string(),
                ]);
            }
        }
    }

    println!("\n=== Figure 4: speedup vs native compiler (>1 beats it) ===\n");
    table.print();
    println!(
        "\nnote: measured at {steps} iterations (paper: 4000) on the simulated \
         NNP-I — compare the ORDERING and who-beats-the-compiler, not absolutes."
    );
    Ok(())
}
