//! Native sparse GNN engine benchmark (ISSUE 8 tentpole): forward cost
//! of the pure-Rust Graph U-Net over the deterministic scaling family,
//! plus population-decode throughput serial vs. parallel.
//!
//! Three sections:
//!
//! * **forward sweep** — one policy forward (`NativeEngine::probs_into`)
//!   at n ∈ {1k, 10k, 100k}. The engine is O(E·H) per layer with no
//!   padding, so the *per-node* cost must stay near-flat; the acceptance
//!   gate is per-node growth ≤ 2× from 10k → 100k.
//! * **dense control arm** — `dense_reference_probs` (the literal O(n²)
//!   model.py transcription used as the parity oracle) at 1k, where it
//!   still fits in the time budget. The sparse/dense ratio at equal n is
//!   the no-ceiling argument in miniature.
//! * **population decode** — a mutated 8-member genome population decoded
//!   serially vs. through the worker pool (`map_parallel_with`, one
//!   reusable `NativeWorkspace` per worker), the shape the fused rollout
//!   engine runs every generation.
//!
//! Writes `BENCH_gnn.json` (`schema: egrl-bench-gnn-v1`), regression-
//! checked by CI against the committed ratio-only baseline in
//! `benches/baselines/BENCH_gnn.json`.

use egrl::bench_harness::Bench;
use egrl::gnn::native::{self, NativeWorkspace};
use egrl::gnn::{perturb_params, NativeEngine};
use egrl::graph::features;
use egrl::utils::json::Json;
use egrl::utils::pool::map_parallel_with;
use egrl::utils::Rng;
use egrl::workloads::synthetic::sized_synthetic;

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new("perf_gnn: native sparse Graph U-Net engine");
    let mut rng = Rng::new(7);
    let params = native::init_actor_params(&mut rng);

    // ---- forward sweep: sparse engine at 1k / 10k / 100k ----------------
    let sizes = [1000usize, 10_000, 100_000];
    let mut rows: Vec<Json> = Vec::new();
    let mut per_node_at = [f64::NAN; 2]; // [10k, 100k]
    let mut native_mean_at_1k = f64::NAN;
    for &n in &sizes {
        let g = sized_synthetic(n);
        let edges = g.edges.len();
        let engine = NativeEngine::for_graph(&g);
        let mut ws = NativeWorkspace::default();
        let label = format!("native forward (n={n})");
        // One warm call outside the timer funds the workspace growth.
        std::hint::black_box(engine.probs_into(&params, &mut ws));
        b.measure(&label, 3, 0.5, || {
            std::hint::black_box(engine.probs_into(&params, &mut ws));
        });
        let mean_s = b.mean_s(&label).unwrap_or(f64::NAN);
        let per_node_us = mean_s / n as f64 * 1e6;
        if n == 1000 {
            native_mean_at_1k = mean_s;
        }
        if n == 10_000 {
            per_node_at[0] = mean_s / n as f64;
        }
        if n == 100_000 {
            per_node_at[1] = mean_s / n as f64;
        }
        println!("    n={n}: {edges} edges, {per_node_us:.3} µs/node");
        rows.push(Json::obj(vec![
            ("nodes", Json::Num(n as f64)),
            ("edges", Json::Num(edges as f64)),
            ("forward_mean_s", Json::Num(mean_s)),
            ("per_node_us", Json::Num(per_node_us)),
        ]));
    }
    let per_node_growth = per_node_at[1] / per_node_at[0];

    // ---- dense control arm at 1k ----------------------------------------
    // The padded-dense oracle prices the same genome over an n×n
    // adjacency; its cost per forward against the sparse engine's is the
    // artifact-ceiling argument measured instead of asserted.
    let dense_mean_at_1k = {
        let n = 1000usize;
        let g = sized_synthetic(n);
        let feats = features::padded_feature_matrix(&g, n);
        let adj = g.normalized_adjacency(n);
        let mask = g.node_mask(n);
        let k = native::pool_k(n);
        let label = "dense reference forward (n=1000)";
        b.measure(label, 2, 0.5, || {
            std::hint::black_box(native::dense_reference_probs(&params, &feats, &adj, &mask, n, k));
        });
        b.mean_s(label).unwrap_or(f64::NAN)
    };
    let dense_over_native_at_1k = dense_mean_at_1k / native_mean_at_1k;

    // ---- population decode: serial vs worker pool -----------------------
    let decode_n = 10_000usize;
    let pop = 8usize;
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(2).min(8);
    let g = sized_synthetic(decode_n);
    let engine = NativeEngine::for_graph(&g);
    let genomes: Vec<Vec<f32>> =
        (0..pop).map(|_| perturb_params(&params, 0.05, 0.5, &mut rng)).collect();
    let mut ws = NativeWorkspace::default();
    let serial_label = format!("decode {pop} members serial (n={decode_n})");
    b.measure_throughput(&serial_label, pop as f64, 3, 0.5, || {
        for gp in &genomes {
            std::hint::black_box(engine.probs_into(gp, &mut ws));
        }
    });
    let par_label = format!("decode {pop} members pool×{threads} (n={decode_n})");
    b.measure_throughput(&par_label, pop as f64, 3, 0.5, || {
        let sums = map_parallel_with(pop, threads, NativeWorkspace::default, |w, i| {
            engine.probs_into(&genomes[i], w).iter().sum::<f32>()
        });
        std::hint::black_box(sums);
    });
    let serial_s = b.mean_s(&serial_label).unwrap_or(f64::NAN);
    let par_s = b.mean_s(&par_label).unwrap_or(f64::NAN);
    let decode_speedup = serial_s / par_s;

    let json = Json::obj(vec![
        ("schema", Json::str("egrl-bench-gnn-v1")),
        ("workload_generator", Json::str("sized_synthetic")),
        ("sizes", Json::arr(sizes.iter().map(|&n| Json::Num(n as f64)))),
        ("per_size", Json::Arr(rows)),
        ("native_per_node_growth_100k_over_10k", Json::Num(per_node_growth)),
        ("target_per_node_growth_100k_over_10k", Json::Num(2.0)),
        ("meets_growth_target", Json::Bool(per_node_growth <= 2.0)),
        ("dense_mean_s_at_1k", Json::Num(dense_mean_at_1k)),
        ("dense_over_native_at_1k", Json::Num(dense_over_native_at_1k)),
        ("decode_threads", Json::Num(threads as f64)),
        ("decode_serial_members_per_s", Json::Num(pop as f64 / serial_s)),
        ("decode_parallel_members_per_s", Json::Num(pop as f64 / par_s)),
        ("parallel_decode_speedup", Json::Num(decode_speedup)),
    ]);
    std::fs::write("BENCH_gnn.json", json.to_string_pretty())?;
    println!("\nwrote BENCH_gnn.json");
    println!(
        "target (ISSUE 8): native per-node forward cost grows ≤ 2x from 10k to 100k — \
         measured {per_node_growth:.2}x; dense/native at 1k: {dense_over_native_at_1k:.1}x; \
         parallel decode: {decode_speedup:.2}x over serial on {threads} threads"
    );
    Ok(())
}
