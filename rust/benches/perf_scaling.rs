//! Scaling benchmark of the incremental-cost core: old vs. new paths
//! swept over synthetic graphs of n ∈ {1k, 4k, 10k, 40k, 100k} nodes,
//! through one deterministic generator (`sized_synthetic`).
//!
//! Three comparisons per size:
//!
//! * **capacity**: the reference scan `CapacityState` vs. the
//!   segment-tree backend, both answering the same 9-way
//!   `move_fits_all` probes (O(n)-ish vs. O(log n));
//! * **batch probe** (ISSUE 7 tentpole): the refold path
//!   (`probe_placements_masked` — per-batch O(n) compensated re-sum of
//!   `totals`) vs. the incremental path
//!   (`probe_placements_masked_cached` — O(degree) deltas against the
//!   `TotalsCache` running total, DESIGN.md §14);
//! * **pricing**: nine `MappingEnv::try_move` calls vs. one
//!   `try_move_batch`, both on the incremental engine (the remaining
//!   gap is shared peak queries + shared noise draws).
//!
//! A fourth arm (ISSUE 8 satellite, ROADMAP item 4 follow-on) re-runs
//! the cached batch probe on the **long-skip** (dense-liveness) family
//! (`sized_synthetic_longskip`: skip edges on ~95% of nodes, arbitrary
//! reach-back) at {10k, 100k}, charting whether the sublinear 10k→100k
//! growth gate holds as liveness density rises.
//!
//! Besides the stdout report, writes `BENCH_scaling.json`
//! (`schema: egrl-bench-scaling-v3`, uploaded and regression-checked by
//! CI against the committed `benches/baselines/BENCH_scaling.json`).
//! Acceptance target (ISSUE 7): the cached batch-probe cost grows ≤ 2×
//! from 10k → 100k nodes while the refold path grows near-linearly.
//! (The old ISSUE 3 "batch ≥ 5× per-move" gate is retired: `try_move`
//! itself now runs on the incremental engine, so that ratio measures
//! batching overhead amortization, not the removed O(n) re-sum.)

use egrl::bench_harness::Bench;
use egrl::env::MappingEnv;
use egrl::mapping::NodePlacement;
use egrl::sim::latency::TotalsCache;
use egrl::utils::json::Json;
use egrl::utils::Rng;
use egrl::workloads::synthetic::{sized_synthetic, sized_synthetic_longskip};

fn main() -> anyhow::Result<()> {
    let sizes = [1000usize, 4000, 10_000, 40_000, 100_000];
    let mut b = Bench::new("perf_scaling: incremental-cost core, old vs new");
    let mut rows: Vec<Json> = Vec::new();
    let mut speedup_at_10k = f64::NAN;
    let mut cached_mean_at = [f64::NAN; 2]; // [10k, 100k]
    let mut refold_mean_at = [f64::NAN; 2];
    let mut refold_over_cached_at_100k = f64::NAN;

    for &n in &sizes {
        let env = MappingEnv::nnpi(sized_synthetic(n), 1);
        let base = env.compiler_map.clone();

        // ---- capacity: scan vs segment tree, same 9-way probes ------------
        let scan = env.compiler.scan_capacity_state(&env.graph, &env.liveness, &base);
        let tree = env.compiler.tree_capacity_state(&env.graph, &env.liveness, &base);
        let mut i_scan = 0usize;
        b.measure_throughput(&format!("capacity 9-way scan (n={n})"), 9.0, 30, 0.3, || {
            let node = i_scan % n;
            i_scan += 1;
            std::hint::black_box(scan.move_fits_all(
                &env.compiler.chip,
                &env.graph,
                &env.liveness,
                &base,
                node,
            ));
        });
        let mut i_tree = 0usize;
        b.measure_throughput(&format!("capacity 9-way segtree (n={n})"), 9.0, 30, 0.3, || {
            let node = i_tree % n;
            i_tree += 1;
            std::hint::black_box(tree.move_fits_all(
                &env.compiler.chip,
                &env.graph,
                &env.liveness,
                &base,
                node,
            ));
        });

        // ---- batch probe: O(n) refold vs incremental running total --------
        // Identical 9-way latency pricing at the `CostTable` layer; the
        // refold path re-sums all n totals per batch, the cached path
        // prices against the maintained compensated running sum.
        let mut totals = Vec::new();
        env.cost_table.node_totals_into(&base, &mut totals);
        let mut skip = Vec::new();
        let mask = [true; 9];
        let mut i_refold = 0usize;
        b.measure_throughput(&format!("batch probe refold (n={n})"), 9.0, 10, 0.3, || {
            let node = i_refold % n;
            i_refold += 1;
            std::hint::black_box(env.cost_table.probe_placements_masked(
                &base,
                node,
                &totals,
                &mut skip,
                &mask,
            ));
        });
        let mut cache = TotalsCache::default();
        cache.rebuild(&env.cost_table, &base);
        let mut i_cached = 0usize;
        b.measure_throughput(&format!("batch probe cached (n={n})"), 9.0, 10, 0.3, || {
            let node = i_cached % n;
            i_cached += 1;
            std::hint::black_box(env.cost_table.probe_placements_masked_cached(
                &base, node, &cache, &mask,
            ));
        });

        // ---- pricing: nine try_move calls vs one try_move_batch ------------
        // Same node stream, same placements (the full 9 per node), no
        // commits — both paths price the identical work.
        let mut st_single = env.search_state(&base);
        let mut rng_single = Rng::new(2);
        let mut k_single = 0usize;
        b.measure_throughput(&format!("pricing try_move ×9 (n={n})"), 9.0, 10, 0.4, || {
            let node = k_single % n;
            k_single += 1;
            for &p in NodePlacement::ALL.iter() {
                std::hint::black_box(env.try_move(&mut st_single, node, p, &mut rng_single));
            }
        });
        let mut st_batch = env.search_state(&base);
        let mut rng_batch = Rng::new(2);
        let mut k_batch = 0usize;
        b.measure_throughput(&format!("pricing try_move_batch (n={n})"), 9.0, 10, 0.4, || {
            let node = k_batch % n;
            k_batch += 1;
            std::hint::black_box(env.try_move_batch(&mut st_batch, node, &mut rng_batch));
        });

        // ---- per-size derived numbers --------------------------------------
        let mean = |label: String| b.mean_s(&label).unwrap_or(f64::NAN);
        let scan_s = mean(format!("capacity 9-way scan (n={n})"));
        let tree_s = mean(format!("capacity 9-way segtree (n={n})"));
        let refold_s = mean(format!("batch probe refold (n={n})"));
        let cached_s = mean(format!("batch probe cached (n={n})"));
        let single_s = mean(format!("pricing try_move ×9 (n={n})"));
        let batch_s = mean(format!("pricing try_move_batch (n={n})"));
        let capacity_speedup = scan_s / tree_s;
        let probe_speedup = refold_s / cached_s;
        let pricing_speedup = single_s / batch_s;
        let single_pps = 9.0 / single_s;
        let batch_pps = 9.0 / batch_s;
        if n == 10_000 {
            speedup_at_10k = pricing_speedup;
            refold_mean_at[0] = refold_s;
            cached_mean_at[0] = cached_s;
        }
        if n == 100_000 {
            refold_mean_at[1] = refold_s;
            cached_mean_at[1] = cached_s;
            refold_over_cached_at_100k = probe_speedup;
        }
        println!(
            "\nn={n}: capacity segtree {capacity_speedup:.1}x vs scan; \
             batch probe cached {probe_speedup:.1}x vs refold; \
             pricing {batch_pps:.0}/s batched vs {single_pps:.0}/s per-move ({pricing_speedup:.1}x)"
        );
        rows.push(Json::obj(vec![
            ("nodes", Json::Num(n as f64)),
            ("capacity_scan_mean_s", Json::Num(scan_s)),
            ("capacity_segtree_mean_s", Json::Num(tree_s)),
            ("capacity_segtree_speedup", Json::Num(capacity_speedup)),
            ("batch_probe_refold_mean_s", Json::Num(refold_s)),
            ("batch_probe_cached_mean_s", Json::Num(cached_s)),
            ("batch_probe_cached_speedup", Json::Num(probe_speedup)),
            ("placements_per_sec_try_move", Json::Num(single_pps)),
            ("placements_per_sec_batch", Json::Num(batch_pps)),
            ("batch_pricing_speedup", Json::Num(pricing_speedup)),
        ]));
    }

    // ---- long-skip (dense-liveness) arm --------------------------------
    // Same cached-batch-probe gate on the denser graph family: per-probe
    // cost is O(degree), so this measures how far liveness density can
    // rise before the 10k→100k growth bound gives.
    let mut longskip_cached_at = [f64::NAN; 2]; // [10k, 100k]
    for (slot, &n) in [10_000usize, 100_000].iter().enumerate() {
        let env = MappingEnv::nnpi(sized_synthetic_longskip(n), 1);
        let base = env.compiler_map.clone();
        let mut cache = TotalsCache::default();
        cache.rebuild(&env.cost_table, &base);
        let mask = [true; 9];
        let mut i = 0usize;
        let label = format!("batch probe cached longskip (n={n})");
        b.measure_throughput(&label, 9.0, 10, 0.3, || {
            let node = i % n;
            i += 1;
            std::hint::black_box(env.cost_table.probe_placements_masked_cached(
                &base, node, &cache, &mask,
            ));
        });
        longskip_cached_at[slot] = b.mean_s(&label).unwrap_or(f64::NAN);
    }
    let longskip_growth = longskip_cached_at[1] / longskip_cached_at[0];

    // Growth of per-batch cost from 10k → 100k: the sublinearity proof.
    // The cached path must stay ≤ 2×; the refold path is the near-10×
    // control arm (it re-sums all n totals every batch).
    let cached_growth = cached_mean_at[1] / cached_mean_at[0];
    let refold_growth = refold_mean_at[1] / refold_mean_at[0];

    let json = Json::obj(vec![
        ("schema", Json::str("egrl-bench-scaling-v3")),
        ("workload_generator", Json::str("sized_synthetic")),
        ("sizes", Json::arr(sizes.iter().map(|&n| Json::Num(n as f64)))),
        ("per_size", Json::Arr(rows)),
        // Informational since ISSUE 7: both arms share the incremental
        // engine, so this is batching amortization, not old-vs-new.
        ("batch_pricing_speedup_at_10k", Json::Num(speedup_at_10k)),
        ("batch_probe_cached_growth_100k_over_10k", Json::Num(cached_growth)),
        ("batch_probe_refold_growth_100k_over_10k", Json::Num(refold_growth)),
        ("target_cached_growth_100k_over_10k", Json::Num(2.0)),
        ("meets_growth_target", Json::Bool(cached_growth <= 2.0)),
        ("batch_probe_cached_speedup_at_100k", Json::Num(refold_over_cached_at_100k)),
        ("longskip_cached_mean_s_10k", Json::Num(longskip_cached_at[0])),
        ("longskip_cached_mean_s_100k", Json::Num(longskip_cached_at[1])),
        ("longskip_cached_growth_100k_over_10k", Json::Num(longskip_growth)),
    ]);
    std::fs::write("BENCH_scaling.json", json.to_string_pretty())?;
    println!("\nwrote BENCH_scaling.json");
    println!(
        "target (ISSUE 7): cached batch-probe cost grows ≤ 2x from 10k to 100k — \
         measured {cached_growth:.2}x (refold control arm: {refold_growth:.2}x; \
         long-skip dense-liveness arm: {longskip_growth:.2}x)"
    );
    Ok(())
}
