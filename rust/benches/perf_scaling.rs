//! Scaling benchmark of the incremental-cost core (ISSUE 3 tentpole):
//! old vs. new paths swept over synthetic graphs of n ∈ {1k, 4k, 10k}
//! nodes, through one deterministic generator (`sized_synthetic`).
//!
//! Two comparisons per size:
//!
//! * **capacity**: the reference scan `CapacityState` vs. the
//!   segment-tree backend, both answering the same 9-way
//!   `move_fits_all` probes (O(n)-ish vs. O(log n));
//! * **pricing**: the per-move `MappingEnv::try_move` loop (nine calls,
//!   each with its own O(n) re-sum — and a full rectify walk on every
//!   invalid candidate) vs. the batched `try_move_batch` (one shared
//!   peak-query set + one shared compensated-sum pass for all nine).
//!
//! Besides the stdout report, writes `BENCH_scaling.json`
//! (`schema: egrl-bench-scaling-v1`, uploaded by CI). Acceptance target:
//! the batched path prices **≥ 5×** more placements/sec than per-move
//! `try_move` at n = 10k.

use egrl::bench_harness::Bench;
use egrl::env::MappingEnv;
use egrl::mapping::NodePlacement;
use egrl::utils::json::Json;
use egrl::utils::Rng;
use egrl::workloads::synthetic::sized_synthetic;

fn main() -> anyhow::Result<()> {
    let sizes = [1000usize, 4000, 10_000];
    let mut b = Bench::new("perf_scaling: incremental-cost core, old vs new");
    let mut rows: Vec<Json> = Vec::new();
    let mut speedup_at_10k = f64::NAN;

    for &n in &sizes {
        let env = MappingEnv::nnpi(sized_synthetic(n), 1);
        let base = env.compiler_map.clone();

        // ---- capacity: scan vs segment tree, same 9-way probes ------------
        let scan = env.compiler.scan_capacity_state(&env.graph, &env.liveness, &base);
        let tree = env.compiler.tree_capacity_state(&env.graph, &env.liveness, &base);
        let mut i_scan = 0usize;
        b.measure_throughput(&format!("capacity 9-way scan (n={n})"), 9.0, 30, 0.3, || {
            let node = i_scan % n;
            i_scan += 1;
            std::hint::black_box(scan.move_fits_all(
                &env.compiler.chip,
                &env.graph,
                &env.liveness,
                &base,
                node,
            ));
        });
        let mut i_tree = 0usize;
        b.measure_throughput(&format!("capacity 9-way segtree (n={n})"), 9.0, 30, 0.3, || {
            let node = i_tree % n;
            i_tree += 1;
            std::hint::black_box(tree.move_fits_all(
                &env.compiler.chip,
                &env.graph,
                &env.liveness,
                &base,
                node,
            ));
        });

        // ---- pricing: nine try_move calls vs one try_move_batch ------------
        // Same node stream, same placements (the full 9 per node), no
        // commits — both paths price the identical work.
        let mut st_single = env.search_state(&base);
        let mut rng_single = Rng::new(2);
        let mut k_single = 0usize;
        b.measure_throughput(&format!("pricing try_move ×9 (n={n})"), 9.0, 10, 0.4, || {
            let node = k_single % n;
            k_single += 1;
            for &p in NodePlacement::ALL.iter() {
                std::hint::black_box(env.try_move(&mut st_single, node, p, &mut rng_single));
            }
        });
        let mut st_batch = env.search_state(&base);
        let mut rng_batch = Rng::new(2);
        let mut k_batch = 0usize;
        b.measure_throughput(&format!("pricing try_move_batch (n={n})"), 9.0, 10, 0.4, || {
            let node = k_batch % n;
            k_batch += 1;
            std::hint::black_box(env.try_move_batch(&mut st_batch, node, &mut rng_batch));
        });

        // ---- per-size derived numbers --------------------------------------
        let mean = |label: String| b.mean_s(&label).unwrap_or(f64::NAN);
        let scan_s = mean(format!("capacity 9-way scan (n={n})"));
        let tree_s = mean(format!("capacity 9-way segtree (n={n})"));
        let single_s = mean(format!("pricing try_move ×9 (n={n})"));
        let batch_s = mean(format!("pricing try_move_batch (n={n})"));
        let capacity_speedup = scan_s / tree_s;
        let pricing_speedup = single_s / batch_s;
        let single_pps = 9.0 / single_s;
        let batch_pps = 9.0 / batch_s;
        if n == 10_000 {
            speedup_at_10k = pricing_speedup;
        }
        println!(
            "\nn={n}: capacity segtree {capacity_speedup:.1}x vs scan; \
             pricing {batch_pps:.0}/s batched vs {single_pps:.0}/s per-move ({pricing_speedup:.1}x)"
        );
        rows.push(Json::obj(vec![
            ("nodes", Json::Num(n as f64)),
            ("capacity_scan_mean_s", Json::Num(scan_s)),
            ("capacity_segtree_mean_s", Json::Num(tree_s)),
            ("capacity_segtree_speedup", Json::Num(capacity_speedup)),
            ("placements_per_sec_try_move", Json::Num(single_pps)),
            ("placements_per_sec_batch", Json::Num(batch_pps)),
            ("batch_pricing_speedup", Json::Num(pricing_speedup)),
        ]));
    }

    let json = Json::obj(vec![
        ("schema", Json::str("egrl-bench-scaling-v1")),
        ("workload_generator", Json::str("sized_synthetic")),
        ("sizes", Json::arr(sizes.iter().map(|&n| Json::Num(n as f64)))),
        ("per_size", Json::Arr(rows)),
        ("batch_pricing_speedup_at_10k", Json::Num(speedup_at_10k)),
        ("target_speedup_at_10k", Json::Num(5.0)),
        ("meets_target", Json::Bool(speedup_at_10k >= 5.0)),
    ]);
    std::fs::write("BENCH_scaling.json", json.to_string_pretty())?;
    println!("\nwrote BENCH_scaling.json");
    println!(
        "target (ISSUE 3): batched pricing ≥ 5x per-move try_move at n=10k — measured {speedup_at_10k:.1}x"
    );
    Ok(())
}
