//! Baseline shootout: every §4 agent on one workload, one table.
//!
//! Runs EA (artifact-free), Greedy-DP and random search side by side on a
//! chosen workload and prints final speedups plus the compiler reference
//! (1.0 by definition) — a fast, no-artifact mini-version of Figure 4.
//!
//! Run: `cargo run --release --example baseline_shootout -- [--workload r50] [--steps 800]`

use std::sync::Arc;

use egrl::agents::{GreedyDp, LocalSearch, MappingAgent, RandomSearch};
use egrl::bench_harness::Table;
use egrl::cli::Cli;
use egrl::config::EgrlConfig;
use egrl::coordinator::{Mode, Trainer};
use egrl::env::MappingEnv;
use egrl::metrics::RunLog;
use egrl::utils::Rng;
use egrl::workloads::Workload;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = Cli::parse(std::iter::once("run".to_string()).chain(args))?;
    let workload = Workload::parse(cli.get_or("workload", "resnet50"))?;
    let steps = cli.get_u64("steps", 800)?;
    let seed = cli.get_u64("seed", 0)?;

    let mut table = Table::new(&["agent", "final speedup", "iterations", "valid best found"]);
    table.row(&["compiler".into(), "1.00 (reference)".into(), "-".into(), "yes".into()]);

    // EA (population of Boltzmann chromosomes — no artifacts needed).
    {
        let env = Arc::new(MappingEnv::nnpi(workload.build(), seed));
        let cfg = EgrlConfig { seed, total_steps: steps, ..Default::default() };
        let mut trainer = Trainer::new(env, cfg, Mode::EaOnly, None)?;
        let mut log = RunLog::new(workload.name(), "ea", seed);
        let res = trainer.run(&mut log)?;
        table.row(&[
            "ea".into(),
            format!("{:.3}", res.best_speedup),
            format!("{}", res.iterations),
            (res.best_speedup > 0.0).to_string(),
        ]);
    }

    // Greedy-DP.
    {
        let env = MappingEnv::nnpi(workload.build(), seed);
        let mut agent = GreedyDp::default();
        let mut rng = Rng::new(seed);
        let mut log = RunLog::new(workload.name(), agent.name(), seed);
        let best = agent.run(&env, steps, &mut rng, &mut log);
        let rect = env.compiler.rectify(&env.graph, &env.liveness, &best);
        table.row(&[
            "greedy-dp".into(),
            format!("{:.3}", env.true_speedup(&rect.map)),
            format!("{}", env.iterations()),
            "yes".into(),
        ]);
    }

    // Local search (incremental move-evaluation engine).
    {
        let env = MappingEnv::nnpi(workload.build(), seed);
        let mut agent = LocalSearch::default();
        let mut rng = Rng::new(seed);
        let mut log = RunLog::new(workload.name(), agent.name(), seed);
        let best = agent.run(&env, steps, &mut rng, &mut log);
        let rect = env.compiler.rectify(&env.graph, &env.liveness, &best);
        table.row(&[
            "local-search".into(),
            format!("{:.3}", env.true_speedup(&rect.map)),
            format!("{}", env.iterations()),
            "yes".into(),
        ]);
    }

    // Random search.
    {
        let env = MappingEnv::nnpi(workload.build(), seed);
        let mut agent = RandomSearch::default();
        let mut rng = Rng::new(seed);
        let mut log = RunLog::new(workload.name(), agent.name(), seed);
        agent.run(&env, steps, &mut rng, &mut log);
        table.row(&[
            "random".into(),
            format!("{:.3}", log.final_speedup()),
            format!("{}", env.iterations()),
            (log.final_speedup() > 0.0).to_string(),
        ]);
    }

    println!("\nbaseline shootout on {} ({} iterations each):\n", workload.name(), steps);
    table.print();
    println!("\n(Fig. 4 shape check: EA > 1.0 > greedy-dp on small budgets; random ~ 0.)");
    Ok(())
}
