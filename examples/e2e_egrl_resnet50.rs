//! End-to-end driver (the DESIGN.md validation workload): the FULL
//! three-layer stack on a real workload.
//!
//!   L3 rust coordinator (this binary)
//!     → PJRT-compiled L2 Graph U-Net + SAC update (AOT HLO artifacts)
//!       → L1 Pallas attention kernels lowered inside them
//!     → NNP-I-class simulator providing the latency reward
//!
//! Trains EGRL (mixed GNN + Boltzmann population, shared replay, SAC
//! learner, migration) on ResNet-50 for several hundred simulated
//! inference runs, logging the speedup curve and SAC losses, and prints
//! the Figure-7-style analysis of the best mapping found. Results are
//! recorded in EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example e2e_egrl_resnet50`
//! Flags: `--steps N` (default 400), `--seed N`.

use std::sync::Arc;

use egrl::cli::Cli;
use egrl::config::EgrlConfig;
use egrl::coordinator::{Mode, Trainer};
use egrl::env::MappingEnv;
use egrl::metrics::RunLog;
use egrl::runtime::Runtime;
use egrl::utils::timer::Timer;
use egrl::viz::{analysis, transition};
use egrl::workloads::Workload;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = Cli::parse(std::iter::once("run".to_string()).chain(args))?;
    let steps = cli.get_u64("steps", 400)?;
    let seed = cli.get_u64("seed", 0)?;

    let dir = Runtime::default_dir();
    anyhow::ensure!(
        dir.join("manifest.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    let rt = Runtime::open(&dir)?;
    rt.verify_smoke()?;
    println!("[e2e] artifacts verified against the Python smoke contract");

    let env = Arc::new(MappingEnv::nnpi(Workload::ResNet50.build(), seed));
    println!(
        "[e2e] resnet50: {} nodes, compiler latency {:.1} µs",
        env.num_nodes(),
        env.compiler_latency_s * 1e6
    );

    let cfg = EgrlConfig {
        seed,
        total_steps: steps,
        // One SAC step per generation keeps the single-core CPU run
        // tractable; the paper's 1-per-env-step setting is
        // `--set update_every=1` via the `egrl train` launcher.
        update_every: 21,
        ..Default::default()
    };
    let t = Timer::start();
    let mut trainer = Trainer::new(env.clone(), cfg, Mode::Egrl, Some(&rt))?;
    println!(
        "[e2e] trainer up in {:.1}s (incl. XLA compile of policy_fwd + sac_update)",
        t.elapsed_s()
    );

    let mut log = RunLog::new("resnet50", "egrl", seed);
    let t = Timer::start();
    let result = trainer.run(&mut log)?;
    println!(
        "[e2e] trained {} iterations / {} generations in {:.1}s",
        result.iterations,
        trainer.generations(),
        t.elapsed_s()
    );

    println!("\n[e2e] speedup curve (iteration → best speedup):");
    for p in log.points.iter().step_by(4.max(log.points.len() / 12)) {
        println!("    {:>5}  {:.3}", p.iteration, p.best_speedup);
    }
    println!(
        "    final  {:.3}  (paper Fig. 4 EGRL on ResNet-50: 1.28)",
        result.best_speedup
    );

    if !log.sac_curve.is_empty() {
        println!("\n[e2e] SAC learner trace (iteration, critic loss, entropy):");
        for (it, cl, ent) in log.sac_curve.iter().step_by(4.max(log.sac_curve.len() / 8)) {
            println!("    {it:>5}  loss {cl:>9.4}  H {ent:.3}");
        }
    }

    println!("\n[e2e] best-map analysis (paper §5.2.1):");
    println!(
        "{}",
        analysis::render_comparison(&env.graph, &env.compiler_map, &result.best_map)
    );
    println!("[e2e] memory-shift matrix (compiler → EGRL):");
    println!(
        "{}",
        transition::render_matrix(&transition::transition_matrix(
            &env.graph,
            &env.compiler_map,
            &result.best_map
        ))
    );
    println!("[e2e] mapping strips (Fig. 7 bottom):");
    print!("{}", transition::render_strips(&env.graph, &env.compiler_map, "compiler"));
    print!("{}", transition::render_strips(&env.graph, &result.best_map, "egrl"));
    Ok(())
}
