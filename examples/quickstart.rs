//! Quickstart: the smallest end-to-end use of the public API.
//!
//! Builds the ResNet-50 workload graph, stands up the NNP-I-class
//! environment (which runs the native-compiler baseline), trains a short
//! EA-only agent (artifact-free — no AOT build needed), and reports the
//! speedup over the compiler together with the §5.2.1 placement
//! statistics.
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::Arc;

use egrl::config::EgrlConfig;
use egrl::coordinator::{Mode, Trainer};
use egrl::env::MappingEnv;
use egrl::metrics::RunLog;
use egrl::viz::analysis;
use egrl::workloads::Workload;

fn main() -> anyhow::Result<()> {
    // 1. Build a workload graph (Table-1 features, 57 operational nodes).
    let graph = Workload::ResNet50.build();
    println!(
        "workload: {} — {} nodes, {:.1} MB weights, action space 3^{}",
        graph.name,
        graph.len(),
        graph.total_weight_bytes() as f64 / (1 << 20) as f64,
        2 * graph.len()
    );

    // 2. Stand up the environment. Constructing it runs the native
    //    compiler heuristic and measures the baseline latency.
    let env = Arc::new(MappingEnv::nnpi(graph, /*seed=*/ 1));
    println!("compiler baseline latency: {:.1} µs", env.compiler_latency_s * 1e6);

    // 3. Train a small EA agent for 600 simulated inference runs.
    let cfg = EgrlConfig { seed: 1, total_steps: 600, ..Default::default() };
    let mut trainer = Trainer::new(env.clone(), cfg, Mode::EaOnly, None)?;
    let mut log = RunLog::new("resnet50", "ea-quickstart", 1);
    let result = trainer.run(&mut log)?;

    // 4. Report.
    println!(
        "after {} iterations: best speedup vs compiler = {:.3}×",
        result.iterations, result.best_speedup
    );
    println!("\nplacement statistics (paper §5.2.1):");
    println!(
        "{}",
        analysis::render_comparison(&env.graph, &env.compiler_map, &result.best_map)
    );
    Ok(())
}
