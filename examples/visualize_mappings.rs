//! Mapping-space visualization (paper §5.2 / Figure 6 + Figure 7).
//!
//! Collects mapping snapshots from an EA training run in two phases —
//! *compiler-competitive* (speedup ≈ 1) and *best* (top speedups) — then:
//!   * computes the Jaccard distance matrix over one-hot encodings,
//!   * embeds it in 2-D with classical MDS (the offline UMAP substitute),
//!   * scores cluster separability with the silhouette coefficient,
//!   * writes the embedding to CSV for plotting,
//!   * prints the Figure-7 transition matrix and mapping strips.
//!
//! Run: `cargo run --release --example visualize_mappings -- [--workload r50]`

use std::sync::Arc;

use egrl::cli::Cli;
use egrl::config::EgrlConfig;
use egrl::coordinator::{Mode, Trainer};
use egrl::env::MappingEnv;
use egrl::mapping::MemoryMap;
use egrl::metrics::RunLog;
use egrl::utils::Rng;
use egrl::viz::{analysis, embed, transition};
use egrl::workloads::Workload;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = Cli::parse(std::iter::once("run".to_string()).chain(args))?;
    let workload = Workload::parse(cli.get_or("workload", "resnet50"))?;
    let seed = cli.get_u64("seed", 0)?;
    let out_csv = cli.get_or("out", "/tmp/egrl_fig6.csv").to_string();

    // Collect mappings along an EA run.
    let env = Arc::new(MappingEnv::nnpi(workload.build(), seed));
    let cfg = EgrlConfig { seed, total_steps: 1500, ..Default::default() };
    let mut trainer = Trainer::new(env.clone(), cfg, Mode::EaOnly, None)?;
    let mut log = RunLog::new(workload.name(), "ea", seed);

    let mut competitive: Vec<MemoryMap> = Vec::new(); // speedup ~ 1
    let mut best: Vec<MemoryMap> = Vec::new(); // top phase
    let mut rng = Rng::new(seed ^ 0xC0FFEE);
    while env.iterations() < 1500 {
        trainer.generation()?;
        // Snapshot the current best map into the phase buckets.
        let map = trainer.best_map().clone();
        let s = env.eval_speedup(&map, &mut rng);
        if (0.9..1.1).contains(&s) && competitive.len() < 24 {
            competitive.push(map);
        } else if s > 1.15 && best.len() < 24 {
            best.push(map);
        }
    }
    let _ = trainer.run(&mut log);
    println!(
        "collected {} compiler-competitive and {} best mappings",
        competitive.len(),
        best.len()
    );
    anyhow::ensure!(
        competitive.len() >= 4 && best.len() >= 4,
        "not enough snapshots collected; try another seed"
    );

    // Figure 6: Jaccard distances → MDS embedding + silhouette.
    let mut maps = competitive.clone();
    maps.extend(best.iter().cloned());
    maps.push(env.compiler_map.clone()); // the red-arrow point
    let labels: Vec<usize> = (0..maps.len())
        .map(|i| if i < competitive.len() { 0 } else { 1 })
        .collect();
    let d = embed::distance_matrix(&maps);
    let coords = embed::mds_2d(&d, maps.len());
    // Silhouette over the two phases (compiler point joins phase 0 — the
    // paper observes it lands inside the competitive cluster).
    let sil = embed::silhouette(&d, maps.len(), &labels);
    println!("silhouette(compiler-competitive vs best) = {sil:.3}  (> 0 ⇒ separable)");

    let mut csv = String::from("x,y,phase\n");
    for (i, (x, y)) in coords.iter().enumerate() {
        let phase = if i == maps.len() - 1 {
            "compiler"
        } else if labels[i] == 0 {
            "competitive"
        } else {
            "best"
        };
        csv.push_str(&format!("{x},{y},{phase}\n"));
    }
    std::fs::write(&out_csv, csv)?;
    println!("MDS embedding written to {out_csv}");

    // Figure 7: transition matrix + strips + §5.2.1 stats.
    let best_map = trainer.best_map();
    println!("\ntransition matrix (compiler → EA best):");
    println!(
        "{}",
        transition::render_matrix(&transition::transition_matrix(
            &env.graph,
            &env.compiler_map,
            best_map
        ))
    );
    println!("mapping strips:");
    print!("{}", transition::render_strips(&env.graph, &env.compiler_map, "compiler"));
    print!("{}", transition::render_strips(&env.graph, best_map, "agent"));
    println!("\n{}", analysis::render_comparison(&env.graph, &env.compiler_map, best_map));
    Ok(())
}
