//! Zero-shot generalization (paper §5.1 / Figure 5).
//!
//! Trains the EGRL GNN policy on one workload, then evaluates the PG
//! actor's mapping on the other two workloads *without fine-tuning*. The
//! same flat parameter vector drives every graph-size artifact variant —
//! the transfer mechanism behind Figure 5.
//!
//! Requires artifacts. Run:
//! `cargo run --release --example generalization -- [--train r50] [--steps 200]`

use std::sync::Arc;

use egrl::bench_harness::Table;
use egrl::cli::Cli;
use egrl::config::EgrlConfig;
use egrl::coordinator::{Mode, Trainer};
use egrl::env::MappingEnv;
use egrl::gnn::PolicyRunner;
use egrl::metrics::RunLog;
use egrl::runtime::Runtime;
use egrl::utils::Rng;
use egrl::workloads::Workload;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = Cli::parse(std::iter::once("run".to_string()).chain(args))?;
    let train_on = Workload::parse(cli.get_or("train", "resnet50"))?;
    let steps = cli.get_u64("steps", 200)?;
    let seed = cli.get_u64("seed", 0)?;

    let rt = Runtime::open(Runtime::default_dir())
        .map_err(|e| anyhow::anyhow!("artifacts required (`make artifacts`): {e}"))?;

    println!("[gen] training EGRL on {} for {steps} iterations ...", train_on.name());
    let env = Arc::new(MappingEnv::nnpi(train_on.build(), seed));
    let cfg = EgrlConfig { seed, total_steps: steps, update_every: 21, ..Default::default() };
    let mut trainer = Trainer::new(env, cfg, Mode::Egrl, Some(&rt))?;
    let mut log = RunLog::new(train_on.name(), "egrl", seed);
    let res = trainer.run(&mut log)?;
    println!("[gen] source-task speedup: {:.3}", res.best_speedup);

    let actor = trainer
        .pg_actor_params()
        .expect("EGRL mode has a PG actor")
        .to_vec();

    let mut table = Table::new(&["eval workload", "zero-shot speedup", "note"]);
    let mut rng = Rng::new(seed ^ 0xF16_5);
    for target in Workload::all() {
        let tenv = MappingEnv::nnpi(target.build(), seed + 100);
        let runner = PolicyRunner::for_env(&rt, &tenv)?;
        let probs = runner.probs(&actor)?;
        let map = runner.greedy_map(&probs);
        let speedup = tenv.eval_speedup(&map, &mut rng);
        let note = if target == train_on { "(training workload)" } else { "zero-shot" };
        table.row(&[
            target.name().into(),
            format!("{speedup:.3}"),
            note.into(),
        ]);
    }
    println!();
    table.print();
    println!("\n(paper Fig. 5: policies transfer 'decently' without fine-tuning —");
    println!(" expect the zero-shot rows to be positive and within ~2× of source.)");
    Ok(())
}
